//! The Cluster Kriging model: partition → (parallel) fit → combine.
//!
//! This is the paper's central contribution (§IV). Complexity: a single
//! Kriging fit is O(n³); partitioning into k clusters gives k·(n/k)³ =
//! n³/k² sequentially, and (n/k)³ with k-way fit parallelism — which
//! [`ClusterKriging::fit`] exploits via the worker pool.

use crate::cluster_kriging::combiner::{ClusterPrediction, Combiner};
use crate::cluster_kriging::partitioner::{Membership, Partition, Partitioner};
use crate::kriging::{HyperOpt, OrdinaryKriging, Prediction, Surrogate};
use crate::obs::trace;
use crate::util::matrix::Matrix;
use crate::util::threadpool::{default_workers, scoped_map};
use anyhow::{bail, Context, Result};

/// Configuration for a Cluster Kriging fit.
pub struct ClusterKrigingConfig {
    pub partitioner: Box<dyn Partitioner>,
    pub combiner: Combiner,
    /// Per-cluster hyper-parameter search settings.
    pub hyperopt: HyperOpt,
    /// Worker threads for the parallel fit (None → machine default).
    pub workers: Option<usize>,
    /// Display name of the flavor ("OWCK", "MTCK", ...).
    pub flavor: String,
}

/// A fitted Cluster Kriging model.
pub struct ClusterKriging {
    models: Vec<OrdinaryKriging>,
    membership: Membership,
    combiner: Combiner,
    flavor: String,
    dim: usize,
    /// Cluster sizes (diagnostics / reports).
    pub cluster_sizes: Vec<usize>,
}

impl ClusterKriging {
    /// Partition `(x, y)` and fit one Kriging model per cluster in
    /// parallel. Clusters that fail to fit (degenerate data) are dropped
    /// with their membership mass redistributed; fitting fails only if
    /// *every* cluster fails.
    pub fn fit(x: &Matrix, y: &[f64], cfg: ClusterKrigingConfig) -> Result<Self> {
        if x.rows() != y.len() {
            bail!("x has {} rows but y has {}", x.rows(), y.len());
        }
        if x.rows() == 0 {
            bail!("empty training set");
        }
        let partition: Partition = cfg.partitioner.partition(x, y);
        if !partition.covers(x.rows()) {
            bail!("partitioner {} produced a non-covering partition", cfg.partitioner.name());
        }

        let workers = cfg.workers.unwrap_or_else(default_workers);
        // Split the worker budget across the k concurrent cluster fits
        // instead of letting each nest a full pool (results are
        // worker-count independent, this is pure scheduling).
        let per_cluster_workers = (workers / partition.clusters.len().max(1)).max(1);
        if let Some(sink) = &cfg.hyperopt.telemetry {
            sink.note(
                "worker-budget",
                &format!(
                    "{workers} workers / {} clusters = {per_cluster_workers} per cluster",
                    partition.clusters.len()
                ),
            );
        }
        // Fit each cluster independently — the paper's parallel step. Each
        // cluster builds one θ-independent distance cache (inside
        // `fit_shared`) that all of its hyperopt objective evaluations
        // reuse, and shares its training slice via Arc instead of cloning
        // it per evaluation.
        let fits: Vec<Result<OrdinaryKriging>> =
            scoped_map(&partition.clusters, workers, |ci, rows| {
                let xs = std::sync::Arc::new(x.select_rows(rows));
                let ys: Vec<f64> = rows.iter().map(|&i| y[i]).collect();
                // Derive a per-cluster seed so restarts differ across
                // clusters but runs stay reproducible.
                let mut opt = cfg.hyperopt.clone();
                opt.seed = cfg.hyperopt.seed.wrapping_add(ci as u64);
                if opt.assembly_workers.is_none() {
                    opt.assembly_workers = Some(per_cluster_workers);
                }
                // Cluster-tag the telemetry handle (if any) so this
                // worker's phase + hyperopt evals are attributed.
                let phase = cfg.hyperopt.telemetry.as_ref().map(|s| {
                    let tagged = s.for_cluster(ci);
                    opt.telemetry = Some(tagged.clone());
                    tagged.phase("cluster-fit")
                });
                let fit =
                    opt.fit_shared(xs, &ys).with_context(|| format!("cluster {ci} fit failed"));
                drop(phase);
                fit
            });

        let mut models = Vec::with_capacity(fits.len());
        let mut kept = Vec::with_capacity(fits.len());
        let mut cluster_sizes = Vec::with_capacity(fits.len());
        for (ci, fit) in fits.into_iter().enumerate() {
            match fit {
                Ok(m) => {
                    cluster_sizes.push(m.n_train());
                    models.push(m);
                    kept.push(ci);
                }
                Err(e) => log::warn!("dropping cluster {ci}: {e:#}"),
            }
        }
        if models.is_empty() {
            bail!("all {} clusters failed to fit", partition.k());
        }

        // If clusters were dropped, remap membership onto the kept set.
        let original_k = partition.k();
        let membership = if kept.len() == original_k {
            partition.membership
        } else {
            remap_membership(partition.membership, kept, original_k)
        };

        Ok(Self {
            models,
            membership,
            combiner: cfg.combiner,
            flavor: cfg.flavor,
            dim: x.cols(),
            cluster_sizes,
        })
    }

    pub fn k(&self) -> usize {
        self.models.len()
    }

    pub fn combiner(&self) -> Combiner {
        self.combiner
    }

    pub fn models(&self) -> &[OrdinaryKriging] {
        &self.models
    }

    /// The fitted routing oracle (weights + hard routes for unseen
    /// points) — the coordinator side of distributed serving routes with
    /// a copy of exactly this.
    pub fn membership(&self) -> &Membership {
        &self.membership
    }

    /// The flavor label ("OWCK", "MTCK", …).
    pub fn flavor(&self) -> &str {
        &self.flavor
    }

    /// Decompose the fitted ensemble into its parts — the sharding
    /// splitter's entry point ([`crate::distributed::ClusterShard::split`]):
    /// `(models, membership, combiner, flavor, dim, cluster_sizes)`.
    pub(crate) fn into_parts(
        self,
    ) -> (Vec<OrdinaryKriging>, Membership, Combiner, String, usize, Vec<usize>) {
        (self.models, self.membership, self.combiner, self.flavor, self.dim, self.cluster_sizes)
    }

    /// Predict one point: gather per-cluster posteriors and combine.
    ///
    /// `SingleModel` only evaluates the routed model (the MTCK prediction
    /// speedup from §IV-C3); the weighting combiners evaluate all k.
    pub fn predict_one(&self, xt: &[f64]) -> ClusterPrediction {
        match self.combiner {
            Combiner::SingleModel => {
                let routed = self.membership.route(xt).min(self.k() - 1);
                let (mean, variance) = self.models[routed].predict_one(xt);
                ClusterPrediction { mean, variance }
            }
            _ => {
                let preds: Vec<ClusterPrediction> = self
                    .models
                    .iter()
                    .map(|m| {
                        let (mean, variance) = m.predict_one(xt);
                        ClusterPrediction { mean, variance }
                    })
                    .collect();
                let weights = self.membership.weights(xt, self.k());
                self.combiner.combine(&preds, &weights, 0)
            }
        }
    }

    /// Batch prediction.
    ///
    /// Weighted combiners evaluate every model over the whole batch with
    /// the blocked predict path (one cross-correlation block + multi-RHS
    /// solve per model); single-model routing groups points per routed
    /// cluster and batches each group — both avoid the per-point solve
    /// the naive loop would pay (§Perf).
    pub fn predict_batch(&self, xt: &Matrix) -> Prediction {
        let m = xt.rows();
        let mut mean = vec![0.0; m];
        let mut variance = vec![0.0; m];
        self.predict_batch_into(xt, &mut mean, &mut variance);
        Prediction { mean, variance }
    }

    /// [`Self::predict_batch`] into caller-provided buffers (the serving
    /// hot path — see [`Surrogate::predict_into`]). `mean` and `variance`
    /// must each hold exactly `xt.rows()` elements.
    pub fn predict_batch_into(&self, xt: &Matrix, mean: &mut [f64], variance: &mut [f64]) {
        let m = xt.rows();
        assert_eq!(mean.len(), m, "predict_batch_into: mean buffer size");
        assert_eq!(variance.len(), m, "predict_batch_into: variance buffer size");
        // Per-cluster predicts run on scoped worker threads; hand the
        // calling thread's ambient trace context across so the models'
        // kernel-assembly / triangular-solve spans land in the tree.
        let ctx = trace::current();
        match self.combiner {
            Combiner::SingleModel => {
                // Group rows by routed cluster, batch-predict per group.
                let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.k()];
                for i in 0..m {
                    groups[self.membership.route(xt.row(i)).min(self.k() - 1)].push(i);
                }
                let outs = scoped_map(&groups, default_workers(), |ci, rows| {
                    if rows.is_empty() {
                        return None;
                    }
                    let _guard = ctx.clone().map(trace::enter);
                    let sub = xt.select_rows(rows);
                    // One assembly worker per model: the map above already
                    // parallelizes across routed groups.
                    Some(self.models[ci].predict_with_workers(&sub, 1).expect("dims checked"))
                });
                for (ci, out) in outs.into_iter().enumerate() {
                    if let Some(pred) = out {
                        for (local, &row) in groups[ci].iter().enumerate() {
                            mean[row] = pred.mean[local];
                            variance[row] = pred.variance[local];
                        }
                    }
                }
            }
            _ => {
                // Every model predicts the full batch (in parallel across
                // models), then combine per point.
                let models: Vec<usize> = (0..self.k()).collect();
                let per_model = scoped_map(&models, default_workers(), |_, &ci| {
                    let _guard = ctx.clone().map(trace::enter);
                    // One assembly worker per model: the map above already
                    // parallelizes across the k models.
                    self.models[ci].predict_with_workers(xt, 1).expect("dims checked")
                });
                trace::span("combine", || {
                    let mut preds = Vec::with_capacity(self.k());
                    for i in 0..m {
                        preds.clear();
                        for pm in &per_model {
                            preds.push(ClusterPrediction {
                                mean: pm.mean[i],
                                variance: pm.variance[i],
                            });
                        }
                        let weights = self.membership.weights(xt.row(i), self.k());
                        let out = self.combiner.combine(&preds, &weights, 0);
                        mean[i] = out.mean;
                        variance[i] = out.variance;
                    }
                });
            }
        }
    }

    /// Serialize the whole fitted ensemble: per-cluster models (with
    /// their factors), the routing oracle and the combiner.
    pub(crate) fn write_artifact(&self, w: &mut crate::util::binio::BinWriter) {
        w.put_str(&self.flavor);
        w.put_u8(match self.combiner {
            Combiner::OptimalWeights => 0,
            Combiner::MembershipMixture => 1,
            Combiner::SingleModel => 2,
        });
        w.put_usize(self.dim);
        w.put_usize_slice(&self.cluster_sizes);
        w.put_usize(self.models.len());
        for m in &self.models {
            m.write_artifact(w);
        }
        self.membership.write_artifact(w);
    }

    /// Inverse of [`Self::write_artifact`].
    pub(crate) fn read_artifact(
        r: &mut crate::util::binio::BinReader<'_>,
        version: u32,
    ) -> anyhow::Result<Self> {
        use anyhow::ensure;
        let flavor = r.get_str()?;
        let combiner = match r.get_u8()? {
            0 => Combiner::OptimalWeights,
            1 => Combiner::MembershipMixture,
            2 => Combiner::SingleModel,
            other => anyhow::bail!("unknown combiner tag {other}"),
        };
        let dim = r.get_usize()?;
        let cluster_sizes = r.get_usize_vec()?;
        let k = r.get_usize()?;
        ensure!(k >= 1, "Cluster Kriging artifact has no models");
        let mut models = Vec::with_capacity(k);
        for _ in 0..k {
            let m = OrdinaryKriging::read_artifact(r, version)?;
            ensure!(
                crate::kriging::Surrogate::dim(&m) == dim,
                "per-cluster model dimension disagrees with ensemble"
            );
            models.push(m);
        }
        let membership = Membership::read_artifact(r)?;
        Ok(Self { models, membership, combiner, flavor, dim, cluster_sizes })
    }
}

impl ClusterKriging {
    /// Absorb one observation into the routed cluster only — the paper's
    /// partition structure applied to online learning: O(n_c²) for the
    /// cluster of size n_c instead of an O(n³) global refit, and the
    /// other k−1 cluster models are untouched. Routing reuses the fitted
    /// [`Membership::route`] oracle, so a point lands in the same cluster
    /// that would serve its single-model prediction.
    pub fn observe_point(&mut self, x: &[f64], y: f64) -> Result<()> {
        if x.len() != self.dim {
            bail!("observe: point has {} dims, model expects {}", x.len(), self.dim);
        }
        let routed = self.membership.route(x).min(self.k() - 1);
        self.models[routed]
            .observe_point(x, y)
            .with_context(|| format!("cluster {routed} observe failed"))?;
        self.cluster_sizes[routed] += 1;
        Ok(())
    }
}

impl crate::online::OnlineSurrogate for ClusterKriging {
    fn observe(&mut self, x: &[f64], y: f64) -> Result<()> {
        self.observe_point(x, y)
    }

    fn training_snapshot(&self) -> (Matrix, Vec<f64>) {
        dedup_snapshot(&self.models, self.dim)
    }

    fn resident_bytes(&self) -> usize {
        // Per-cluster factors, not the deduped snapshot estimate — the
        // whole point of the partition is that Σ n_c² ≪ n².
        self.models.iter().map(|m| m.resident_bytes()).sum()
    }
}

/// Distinct training observations across a set of per-cluster models.
/// Overlapping partitioners (OWFCK/GMMCK) store boundary points in
/// several clusters; return each distinct observation once so a refit
/// does not see artificial duplicates. The key covers (x, y) bits: a
/// genuine overlap duplicate shares both, while repeated measurements at
/// one design point (same x, different y) are real data and must all
/// survive into the refit history. Shared by [`ClusterKriging`] and the
/// split-off [`crate::distributed::ClusterShard`].
pub(crate) fn dedup_snapshot(models: &[OrdinaryKriging], dim: usize) -> (Matrix, Vec<f64>) {
    let mut seen = std::collections::HashSet::new();
    let mut x = Vec::new();
    let mut y = Vec::new();
    for m in models {
        let (xs, ys) = (m.x_train(), m.y_train());
        for i in 0..xs.rows() {
            let mut key: Vec<u64> = xs.row(i).iter().map(|v| v.to_bits()).collect();
            key.push(ys[i].to_bits());
            if seen.insert(key) {
                x.extend_from_slice(xs.row(i));
                y.push(ys[i]);
            }
        }
    }
    (Matrix::from_vec(y.len(), dim, x), y)
}

impl Surrogate for ClusterKriging {
    fn predict(&self, xt: &Matrix) -> Result<Prediction> {
        Ok(self.predict_batch(xt))
    }

    fn as_online(&self) -> Option<&dyn crate::online::OnlineSurrogate> {
        Some(self)
    }

    fn as_online_mut(&mut self) -> Option<&mut dyn crate::online::OnlineSurrogate> {
        Some(self)
    }

    fn name(&self) -> &str {
        &self.flavor
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn predict_into(&self, xt: &Matrix, mean: &mut [f64], variance: &mut [f64]) -> Result<()> {
        self.predict_batch_into(xt, mean, variance);
        Ok(())
    }

    fn shard_predictor(&self) -> Option<&dyn crate::distributed::ShardPredictor> {
        // A monolithic ensemble serves `spredict` for ALL its clusters —
        // a one-shard topology, and the reference a sharded deployment is
        // checked against.
        Some(self)
    }

    fn health_report(&self) -> Option<crate::obs::health::HealthReport> {
        let clusters = self
            .models
            .iter()
            .enumerate()
            .map(|(ci, m)| crate::obs::health::ClusterHealth {
                cluster: ci,
                health: m.health_or_probe(),
            })
            .collect();
        Some(crate::obs::health::HealthReport { clusters })
    }

    fn save(&self, w: &mut dyn std::io::Write) -> Result<()> {
        let mut payload = crate::util::binio::BinWriter::new();
        self.write_artifact(&mut payload);
        crate::surrogate::artifact::write_model(
            w,
            crate::surrogate::artifact::TAG_CLUSTER_KRIGING,
            &payload.into_bytes(),
        )
    }
}

/// Remap a membership oracle after dropping clusters (see
/// [`Membership::Remapped`]): weights of dropped clusters are discarded
/// and the rest renormalized; hard routes to a dropped cluster fall back
/// to the first kept one.
fn remap_membership(membership: Membership, kept: Vec<usize>, original_k: usize) -> Membership {
    Membership::Remapped { inner: Box::new(membership), kept, original_k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster_kriging::builder;
    use crate::kriging::hyperopt::NuggetMode;
    use crate::util::proptest::gen_matrix;
    use crate::util::rng::Rng;

    fn smooth_dataset(n: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = gen_matrix(&mut rng, n, 2, -3.0, 3.0);
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let r = x.row(i);
                (r[0]).sin() + 0.3 * r[1] * r[1]
            })
            .collect();
        (x, y)
    }

    fn fast_hyperopt() -> HyperOpt {
        HyperOpt {
            restarts: 1,
            max_evals: 15,
            isotropic: true,
            nugget: NuggetMode::Fixed(1e-8),
            ..HyperOpt::default()
        }
    }

    #[test]
    fn owck_fits_and_predicts_accurately() {
        let (x, y) = smooth_dataset(160, 1);
        let model = ClusterKriging::fit(
            &x,
            &y,
            ClusterKrigingConfig {
                partitioner: Box::new(
                    crate::cluster_kriging::partitioner::KMeansPartitioner { k: 4, seed: 2 },
                ),
                combiner: Combiner::OptimalWeights,
                hyperopt: fast_hyperopt(),
                workers: Some(4),
                flavor: "OWCK".into(),
            },
        )
        .unwrap();
        assert_eq!(model.k(), 4);
        // In-sample accuracy should be high for smooth data.
        let pred = model.predict_batch(&x);
        let sse: f64 =
            pred.mean.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() / y.len() as f64;
        let var = crate::util::stats::variance(&y);
        assert!(sse / var < 0.05, "SMSE {} too high", sse / var);
    }

    #[test]
    fn all_flavors_produce_finite_predictions() {
        let (x, y) = smooth_dataset(120, 3);
        let mut rng = Rng::new(4);
        let xt = gen_matrix(&mut rng, 20, 2, -3.0, 3.0);
        for flavor in ["OWCK", "OWFCK", "GMMCK", "MTCK"] {
            let cfg = builder::flavor(flavor, 3, 7, fast_hyperopt()).unwrap();
            let model = ClusterKriging::fit(&x, &y, cfg).unwrap();
            let pred = model.predict_batch(&xt);
            assert!(pred.mean.iter().all(|v| v.is_finite()), "{flavor}: non-finite mean");
            assert!(
                pred.variance.iter().all(|v| v.is_finite() && *v >= 0.0),
                "{flavor}: bad variance"
            );
        }
    }

    #[test]
    fn k1_matches_plain_kriging() {
        // Cluster Kriging with one cluster must equal ordinary Kriging.
        let (x, y) = smooth_dataset(50, 5);
        let opt = fast_hyperopt();
        let plain = opt.fit(x.clone(), &y).unwrap();
        let ck = ClusterKriging::fit(
            &x,
            &y,
            ClusterKrigingConfig {
                partitioner: Box::new(
                    crate::cluster_kriging::partitioner::KMeansPartitioner { k: 1, seed: 1 },
                ),
                combiner: Combiner::OptimalWeights,
                hyperopt: opt,
                workers: Some(1),
                flavor: "OWCK".into(),
            },
        )
        .unwrap();
        let mut rng = Rng::new(6);
        let xt = gen_matrix(&mut rng, 10, 2, -2.0, 2.0);
        let pp = plain.predict(&xt).unwrap();
        let pc = ck.predict_batch(&xt);
        for i in 0..10 {
            assert!((pp.mean[i] - pc.mean[i]).abs() < 1e-9, "mean differs at {i}");
            assert!((pp.variance[i] - pc.variance[i]).abs() < 1e-9, "var differs at {i}");
        }
    }

    #[test]
    fn mtck_only_uses_routed_model() {
        let (x, y) = smooth_dataset(100, 7);
        let cfg = builder::flavor("MTCK", 4, 11, fast_hyperopt()).unwrap();
        let model = ClusterKriging::fit(&x, &y, cfg).unwrap();
        // SingleModel prediction equals the routed model's own prediction.
        let probe = [0.5, -0.5];
        let out = model.predict_one(&probe);
        let any_match = model.models().iter().any(|m| {
            let (mu, var) = m.predict_one(&probe);
            (mu - out.mean).abs() < 1e-12 && (var - out.variance).abs() < 1e-12
        });
        assert!(any_match, "MTCK output doesn't match any single model");
    }

    #[test]
    fn observe_updates_only_routed_cluster() {
        let (x, y) = smooth_dataset(120, 11);
        let cfg = builder::flavor("OWCK", 3, 5, fast_hyperopt()).unwrap();
        let mut model = ClusterKriging::fit(&x, &y, cfg).unwrap();
        let before: Vec<usize> = model.models().iter().map(|m| m.n_train()).collect();
        let probe = [1.2, -0.8];
        model.observe_point(&probe, 0.77).unwrap();
        let after: Vec<usize> = model.models().iter().map(|m| m.n_train()).collect();
        let grown: Vec<usize> =
            (0..before.len()).filter(|&i| after[i] != before[i]).collect();
        assert_eq!(grown.len(), 1, "exactly one cluster must grow: {before:?} -> {after:?}");
        assert_eq!(after[grown[0]], before[grown[0]] + 1);
        assert_eq!(model.cluster_sizes[grown[0]], after[grown[0]]);
        // A second observation at the same point lands in the same cluster.
        model.observe_point(&probe, 0.78).unwrap();
        assert_eq!(model.models()[grown[0]].n_train(), before[grown[0]] + 2);
        // Dimension mismatch is a recoverable error.
        assert!(model.observe_point(&[1.0], 0.0).is_err());
    }

    #[test]
    fn training_snapshot_dedups_overlapping_clusters() {
        use crate::online::OnlineSurrogate as _;
        let (x, y) = smooth_dataset(90, 13);
        let cfg = builder::flavor("OWFCK", 3, 7, fast_hyperopt()).unwrap();
        let model = ClusterKriging::fit(&x, &y, cfg).unwrap();
        let stored: usize = model.models().iter().map(|m| m.n_train()).sum();
        let (sx, sy) = model.training_snapshot();
        assert_eq!(sx.rows(), sy.len());
        assert_eq!(sx.rows(), 90, "snapshot must contain each point once");
        assert!(stored >= 90, "overlap partitioner should duplicate boundary points");
    }

    #[test]
    fn fit_errors_on_bad_input() {
        let cfg = builder::flavor("OWCK", 2, 1, fast_hyperopt()).unwrap();
        assert!(ClusterKriging::fit(&Matrix::zeros(0, 2), &[], cfg).is_err());
        let cfg = builder::flavor("OWCK", 2, 1, fast_hyperopt()).unwrap();
        assert!(
            ClusterKriging::fit(&Matrix::zeros(3, 2), &[1.0, 2.0], cfg).is_err(),
            "length mismatch accepted"
        );
    }

    #[test]
    fn parallel_and_serial_fits_agree() {
        let (x, y) = smooth_dataset(80, 9);
        let fit = |workers| {
            ClusterKriging::fit(
                &x,
                &y,
                ClusterKrigingConfig {
                    partitioner: Box::new(
                        crate::cluster_kriging::partitioner::KMeansPartitioner { k: 4, seed: 3 },
                    ),
                    combiner: Combiner::OptimalWeights,
                    hyperopt: fast_hyperopt(),
                    workers: Some(workers),
                    flavor: "OWCK".into(),
                },
            )
            .unwrap()
        };
        let serial = fit(1);
        let parallel = fit(4);
        let probe = [1.0, 1.0];
        let a = serial.predict_one(&probe);
        let b = parallel.predict_one(&probe);
        assert!((a.mean - b.mean).abs() < 1e-12);
        assert!((a.variance - b.variance).abs() < 1e-12);
    }
}
