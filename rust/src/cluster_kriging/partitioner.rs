//! Partitioning stage of the Cluster Kriging framework (paper §IV-A).
//!
//! A [`Partitioner`] turns a training set into (possibly overlapping)
//! clusters of row indices plus a [`Membership`] oracle used at prediction
//! time to weight or route among the per-cluster models.

use crate::clustering::{fcm, gmm, kmeans, random, regression_tree};
use crate::util::binio::{BinReader, BinWriter};
use crate::util::matrix::Matrix;

/// How a fitted partition assigns an *unseen* point to clusters.
///
/// Each variant carries the concrete fitted routing state (centroids,
/// mixture components, tree nodes) rather than a closure, so a fitted
/// Cluster Kriging model can be serialized to an artifact and reloaded
/// with bit-identical routing — the closure representation this replaced
/// could predict but never persist.
pub enum Membership {
    /// Hard nearest-centroid assignment (k-means and random partitioners).
    Centroids(Matrix),
    /// Fuzzy C-means soft membership: Eq. 9 at the fitted centroids.
    Fcm { centroids: Matrix, fuzzifier: f64 },
    /// GMM posterior responsibilities (Eq. 13 weights).
    Gmm(gmm::Gmm),
    /// Regression-tree hard routing (MTCK).
    Tree(regression_tree::RegressionTree),
    /// Post-fit remap after degenerate clusters were dropped: weights of
    /// dropped clusters are discarded and renormalized; hard routes to a
    /// dropped cluster fall back to the first kept one. `kept` holds the
    /// surviving original cluster indices, `original_k` the pre-drop
    /// cluster count the inner oracle still answers for.
    Remapped { inner: Box<Membership>, kept: Vec<usize>, original_k: usize },
}

impl Membership {
    /// Whether the oracle produces graded weights (vs one-hot routing).
    pub fn is_soft(&self) -> bool {
        match self {
            Membership::Centroids(_) | Membership::Tree(_) => false,
            Membership::Fcm { .. } | Membership::Gmm(_) => true,
            Membership::Remapped { inner, .. } => inner.is_soft(),
        }
    }

    /// Weight vector for a point (hard assignments become one-hot).
    pub fn weights(&self, x: &[f64], k: usize) -> Vec<f64> {
        match self {
            Membership::Fcm { centroids, fuzzifier } => {
                fcm::membership_for(centroids, *fuzzifier, x)
            }
            Membership::Gmm(g) => g.membership_of(x),
            Membership::Remapped { inner, kept, original_k } if inner.is_soft() => {
                let full = inner.weights(x, *original_k);
                let mut w: Vec<f64> = kept.iter().map(|&c| full[c]).collect();
                let s: f64 = w.iter().sum();
                if s > 1e-12 {
                    for v in &mut w {
                        *v /= s;
                    }
                } else {
                    let u = 1.0 / w.len() as f64;
                    for v in &mut w {
                        *v = u;
                    }
                }
                w
            }
            hard => {
                let mut w = vec![0.0; k];
                w[hard.route(x).min(k - 1)] = 1.0;
                w
            }
        }
    }

    /// Single cluster choice (soft assignments take the argmax).
    pub fn route(&self, x: &[f64]) -> usize {
        match self {
            Membership::Centroids(centers) => {
                kmeans::assign(centers, &Matrix::from_vec(1, x.len(), x.to_vec()))[0]
            }
            Membership::Tree(tree) => tree.route(x),
            Membership::Remapped { inner, kept, .. } => {
                if inner.is_soft() {
                    crate::util::stats::argmax(&self.weights(x, kept.len()))
                } else {
                    let original = inner.route(x);
                    kept.iter().position(|&c| c == original).unwrap_or(0)
                }
            }
            soft => crate::util::stats::argmax(&soft.weights(x, 0)),
        }
    }

    /// Deep copy via the artifact encoding — the oracle variants carry
    /// fitted state (mixture components, tree nodes) that deliberately
    /// doesn't implement `Clone`, but every one of them round-trips
    /// bit-identically through [`Self::write_artifact`], so the encoding
    /// doubles as the one clone path (sharding hands each shard its own
    /// copy of the routing oracle).
    pub fn deep_clone(&self) -> Membership {
        let mut w = BinWriter::new();
        self.write_artifact(&mut w);
        let bytes = w.into_bytes();
        Membership::read_artifact(&mut BinReader::new(&bytes))
            .expect("membership artifact roundtrip cannot fail on a valid oracle")
    }

    /// Serialize the routing oracle into a model artifact payload.
    pub(crate) fn write_artifact(&self, w: &mut BinWriter) {
        match self {
            Membership::Centroids(centers) => {
                w.put_u8(0);
                w.put_matrix(centers);
            }
            Membership::Fcm { centroids, fuzzifier } => {
                w.put_u8(1);
                w.put_matrix(centroids);
                w.put_f64(*fuzzifier);
            }
            Membership::Gmm(g) => {
                w.put_u8(2);
                g.write_artifact(w);
            }
            Membership::Tree(tree) => {
                w.put_u8(3);
                tree.write_artifact(w);
            }
            Membership::Remapped { inner, kept, original_k } => {
                w.put_u8(4);
                inner.write_artifact(w);
                w.put_usize_slice(kept);
                w.put_usize(*original_k);
            }
        }
    }

    /// Inverse of [`Self::write_artifact`].
    pub(crate) fn read_artifact(r: &mut BinReader<'_>) -> anyhow::Result<Self> {
        use anyhow::{bail, ensure};
        Ok(match r.get_u8()? {
            0 => Membership::Centroids(r.get_matrix()?),
            1 => Membership::Fcm { centroids: r.get_matrix()?, fuzzifier: r.get_f64()? },
            2 => Membership::Gmm(gmm::Gmm::read_artifact(r)?),
            3 => Membership::Tree(regression_tree::RegressionTree::read_artifact(r)?),
            4 => {
                let inner = Box::new(Membership::read_artifact(r)?);
                let kept = r.get_usize_vec()?;
                let original_k = r.get_usize()?;
                ensure!(
                    !kept.is_empty() && kept.iter().all(|&c| c < original_k),
                    "remapped membership artifact inconsistent"
                );
                Membership::Remapped { inner, kept, original_k }
            }
            other => bail!("unknown membership tag {other}"),
        })
    }
}

/// Result of partitioning a training set.
pub struct Partition {
    /// Row indices per cluster. May overlap (FCM/GMM with o > 1) but must
    /// cover every row.
    pub clusters: Vec<Vec<usize>>,
    /// Unseen-point membership oracle.
    pub membership: Membership,
}

impl Partition {
    pub fn k(&self) -> usize {
        self.clusters.len()
    }

    /// Validate coverage (every training row in ≥ 1 cluster).
    pub fn covers(&self, n: usize) -> bool {
        let mut seen = vec![false; n];
        for cl in &self.clusters {
            for &i in cl {
                if i >= n {
                    return false;
                }
                seen[i] = true;
            }
        }
        seen.iter().all(|&s| s)
    }
}

/// A partitioning strategy: the pluggable first stage of Cluster Kriging.
pub trait Partitioner: Send + Sync {
    /// Partition `(x, y)` into clusters.
    fn partition(&self, x: &Matrix, y: &[f64]) -> Partition;
    fn name(&self) -> &'static str;
}

/// K-means hard partitioner (OWCK).
#[derive(Debug, Clone)]
pub struct KMeansPartitioner {
    pub k: usize,
    pub seed: u64,
}

impl Partitioner for KMeansPartitioner {
    fn partition(&self, x: &Matrix, _y: &[f64]) -> Partition {
        let km = kmeans::fit(
            x,
            &kmeans::KMeansConfig { seed: self.seed, ..kmeans::KMeansConfig::new(self.k) },
        );
        let k = self.k;
        let mut clusters = vec![Vec::new(); k];
        for (i, &l) in km.labels.iter().enumerate() {
            clusters[l].push(i);
        }
        Partition { clusters, membership: Membership::Centroids(km.centroids) }
    }

    fn name(&self) -> &'static str {
        "kmeans"
    }
}

/// Fuzzy C-means overlapping partitioner (OWFCK). `overlap` is the paper's
/// `o ∈ [1, 2]`; the paper's experiments use 10% overlap → o = 1.1.
#[derive(Debug, Clone)]
pub struct FcmPartitioner {
    pub k: usize,
    pub overlap: f64,
    pub seed: u64,
}

impl Partitioner for FcmPartitioner {
    fn partition(&self, x: &Matrix, _y: &[f64]) -> Partition {
        let f = fcm::fit(
            x,
            &fcm::FcmConfig { seed: self.seed, ..fcm::FcmConfig::new(self.k) },
        );
        let clusters = f.overlapping_assignment(self.overlap);
        Partition {
            clusters,
            membership: Membership::Fcm { centroids: f.centroids, fuzzifier: f.fuzzifier },
        }
    }

    fn name(&self) -> &'static str {
        "fcm"
    }
}

/// Gaussian-mixture overlapping partitioner (GMMCK).
#[derive(Debug, Clone)]
pub struct GmmPartitioner {
    pub k: usize,
    pub overlap: f64,
    pub covariance: gmm::CovarianceType,
    pub seed: u64,
}

impl GmmPartitioner {
    pub fn new(k: usize) -> Self {
        Self { k, overlap: 1.1, covariance: gmm::CovarianceType::Diagonal, seed: 0x96 }
    }
}

impl Partitioner for GmmPartitioner {
    fn partition(&self, x: &Matrix, _y: &[f64]) -> Partition {
        let g = gmm::fit(
            x,
            &gmm::GmmConfig {
                covariance: self.covariance,
                seed: self.seed,
                ..gmm::GmmConfig::new(self.k)
            },
        );
        let clusters = g.overlapping_assignment(self.overlap);
        Partition {
            clusters,
            // The responsibilities matrix is fit-time state; the routing
            // oracle only needs the mixture components.
            membership: Membership::Gmm(g.without_responsibilities()),
        }
    }

    fn name(&self) -> &'static str {
        "gmm"
    }
}

/// Regression-tree objective-space partitioner (MTCK).
#[derive(Debug, Clone)]
pub struct TreePartitioner {
    /// Target number of leaves (clusters).
    pub leaves: usize,
    /// Optional explicit min leaf size (else derived from `leaves`).
    pub min_leaf_size: Option<usize>,
}

impl Partitioner for TreePartitioner {
    fn partition(&self, x: &Matrix, y: &[f64]) -> Partition {
        let cfg = match self.min_leaf_size {
            Some(m) => regression_tree::TreeConfig {
                max_leaves: Some(self.leaves),
                ..regression_tree::TreeConfig::new(m)
            },
            None => regression_tree::TreeConfig::with_max_leaves(x.rows(), self.leaves),
        };
        let tree = regression_tree::fit(x, y, &cfg);
        let clusters = tree.clusters.clone();
        Partition { clusters, membership: Membership::Tree(tree) }
    }

    fn name(&self) -> &'static str {
        "regression_tree"
    }
}

/// Random partitioner (ablation baseline; routes unseen points to the
/// nearest cluster mean so predictions remain well-defined).
#[derive(Debug, Clone)]
pub struct RandomPartitioner {
    pub k: usize,
    pub seed: u64,
}

impl Partitioner for RandomPartitioner {
    fn partition(&self, x: &Matrix, _y: &[f64]) -> Partition {
        let clusters = random::partition(x.rows(), self.k, self.seed);
        // Mean of each random cluster for unseen routing.
        let d = x.cols();
        let mut means = Matrix::zeros(self.k, d);
        for (c, cl) in clusters.iter().enumerate() {
            for &i in cl {
                let xi = x.row(i);
                let row = means.row_mut(c);
                for j in 0..d {
                    row[j] += xi[j] / cl.len() as f64;
                }
            }
        }
        Partition { clusters, membership: Membership::Centroids(means) }
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check_default, gen_matrix, gen_size, gen_vec};

    fn partitioners(k: usize, seed: u64) -> Vec<Box<dyn Partitioner>> {
        vec![
            Box::new(KMeansPartitioner { k, seed }),
            Box::new(FcmPartitioner { k, overlap: 1.1, seed }),
            Box::new(GmmPartitioner { seed, ..GmmPartitioner::new(k) }),
            Box::new(TreePartitioner { leaves: k, min_leaf_size: None }),
            Box::new(RandomPartitioner { k, seed }),
        ]
    }

    #[test]
    fn all_partitioners_cover_data_prop() {
        check_default(|rng| {
            let n = gen_size(rng, 20, 60);
            let k = gen_size(rng, 2, 4);
            let x = gen_matrix(rng, n, 2, -3.0, 3.0);
            let y = gen_vec(rng, n, -1.0, 1.0);
            for p in partitioners(k, rng.next_u64()) {
                let part = p.partition(&x, &y);
                crate::prop_assert!(part.covers(n), "{}: coverage hole", p.name());
                crate::prop_assert!(part.k() >= 1 && part.k() <= k, "{}: bad k", p.name());
            }
            Ok(())
        });
    }

    #[test]
    fn membership_weights_simplex_prop() {
        check_default(|rng| {
            let n = gen_size(rng, 20, 50);
            let k = 3;
            let x = gen_matrix(rng, n, 2, -2.0, 2.0);
            let y = gen_vec(rng, n, -1.0, 1.0);
            for p in partitioners(k, rng.next_u64()) {
                let part = p.partition(&x, &y);
                let probe = gen_vec(rng, 2, -2.0, 2.0);
                let w = part.membership.weights(&probe, part.k());
                crate::prop_assert!(w.len() == part.k(), "{}: wrong weight len", p.name());
                let s: f64 = w.iter().sum();
                crate::prop_assert!((s - 1.0).abs() < 1e-6, "{}: weights sum {s}", p.name());
                let r = part.membership.route(&probe);
                crate::prop_assert!(r < part.k(), "{}: route out of range", p.name());
            }
            Ok(())
        });
    }

    #[test]
    fn hard_partitioners_are_disjoint() {
        let mut rng = crate::util::rng::Rng::new(3);
        let x = gen_matrix(&mut rng, 50, 2, -2.0, 2.0);
        let y: Vec<f64> = (0..50).map(|i| x.row(i)[0]).collect();
        for p in [
            &KMeansPartitioner { k: 4, seed: 1 } as &dyn Partitioner,
            &TreePartitioner { leaves: 4, min_leaf_size: None },
            &RandomPartitioner { k: 4, seed: 1 },
        ] {
            let part = p.partition(&x, &y);
            let total: usize = part.clusters.iter().map(|c| c.len()).sum();
            assert_eq!(total, 50, "{}: overlapping clusters", p.name());
        }
    }

    #[test]
    fn route_consistent_with_hard_weights() {
        let mut rng = crate::util::rng::Rng::new(4);
        let x = gen_matrix(&mut rng, 40, 2, -2.0, 2.0);
        let y: Vec<f64> = (0..40).map(|i| x.row(i)[1]).collect();
        let part = KMeansPartitioner { k: 3, seed: 5 }.partition(&x, &y);
        let probe = [0.3, -0.7];
        let w = part.membership.weights(&probe, part.k());
        let r = part.membership.route(&probe);
        assert_eq!(w[r], 1.0);
        assert_eq!(w.iter().filter(|&&v| v > 0.0).count(), 1);
    }
}
