//! Flavor presets — the paper's four Cluster Kriging algorithms (§V).
//!
//! | name  | partitioner          | combiner            |
//! |-------|----------------------|---------------------|
//! | OWCK  | k-means              | optimal weights     |
//! | OWFCK | fuzzy C-means (o=1.1)| optimal weights     |
//! | GMMCK | GMM (o=1.1)          | membership mixture  |
//! | MTCK  | regression tree      | single model        |
//!
//! Plus `RANDOM-CK` (random partition + optimal weights) as the ablation
//! flavor quantifying the value of informed partitioning.
//!
//! Every flavor's per-cluster hyperopt runs against one θ-independent
//! [`crate::kernel::cache::DistanceCache`] per cluster (built inside
//! `HyperOpt::fit_shared`), so the ~restarts×evals objective evaluations
//! reassemble the correlation matrix from cached distance planes instead
//! of recomputing it from raw points. `HyperOpt::assembly_workers` can be
//! left at `None` here: `ClusterKriging::fit` splits the worker budget
//! across the k concurrent cluster fits automatically.

use crate::cluster_kriging::combiner::Combiner;
use crate::cluster_kriging::model::ClusterKrigingConfig;
use crate::cluster_kriging::partitioner::{
    FcmPartitioner, GmmPartitioner, KMeansPartitioner, RandomPartitioner, TreePartitioner,
};
use crate::kriging::HyperOpt;
use anyhow::bail;

/// Overlap used by the paper's experiments (§VI-A: "overlap … set to 10%").
pub const PAPER_OVERLAP: f64 = 1.1;

/// All flavor names accepted by [`flavor`].
pub const FLAVORS: [&str; 5] = ["OWCK", "OWFCK", "GMMCK", "MTCK", "RANDOM-CK"];

/// Build the configuration for a named flavor with `k` clusters.
pub fn flavor(
    name: &str,
    k: usize,
    seed: u64,
    hyperopt: HyperOpt,
) -> anyhow::Result<ClusterKrigingConfig> {
    let cfg = match name {
        "OWCK" => ClusterKrigingConfig {
            partitioner: Box::new(KMeansPartitioner { k, seed }),
            combiner: Combiner::OptimalWeights,
            hyperopt,
            workers: None,
            flavor: "OWCK".into(),
        },
        "OWFCK" => ClusterKrigingConfig {
            partitioner: Box::new(FcmPartitioner { k, overlap: PAPER_OVERLAP, seed }),
            combiner: Combiner::OptimalWeights,
            hyperopt,
            workers: None,
            flavor: "OWFCK".into(),
        },
        "GMMCK" => ClusterKrigingConfig {
            partitioner: Box::new(GmmPartitioner {
                seed,
                overlap: PAPER_OVERLAP,
                ..GmmPartitioner::new(k)
            }),
            combiner: Combiner::MembershipMixture,
            hyperopt,
            workers: None,
            flavor: "GMMCK".into(),
        },
        "MTCK" => ClusterKrigingConfig {
            partitioner: Box::new(TreePartitioner { leaves: k, min_leaf_size: None }),
            combiner: Combiner::SingleModel,
            hyperopt,
            workers: None,
            flavor: "MTCK".into(),
        },
        "RANDOM-CK" => ClusterKrigingConfig {
            partitioner: Box::new(RandomPartitioner { k, seed }),
            combiner: Combiner::OptimalWeights,
            hyperopt,
            workers: None,
            flavor: "RANDOM-CK".into(),
        },
        other => bail!("unknown Cluster Kriging flavor {other:?} (expected one of {FLAVORS:?})"),
    };
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_flavors_buildable() {
        for name in FLAVORS {
            let cfg = flavor(name, 4, 1, HyperOpt::default()).unwrap();
            assert_eq!(cfg.flavor, name);
        }
    }

    #[test]
    fn unknown_flavor_rejected() {
        assert!(flavor("BOGUS", 2, 1, HyperOpt::default()).is_err());
    }

    #[test]
    fn combiners_match_paper_table() {
        assert_eq!(
            flavor("OWCK", 2, 1, HyperOpt::default()).unwrap().combiner,
            Combiner::OptimalWeights
        );
        assert_eq!(
            flavor("GMMCK", 2, 1, HyperOpt::default()).unwrap().combiner,
            Combiner::MembershipMixture
        );
        assert_eq!(
            flavor("MTCK", 2, 1, HyperOpt::default()).unwrap().combiner,
            Combiner::SingleModel
        );
    }
}
