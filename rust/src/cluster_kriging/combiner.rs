//! Prediction-combination stage of Cluster Kriging (paper §IV-C).
//!
//! Three schemes, matching the paper:
//! * [`Combiner::OptimalWeights`] — inverse-variance weights minimizing the
//!   combined Kriging variance (Eq. 11–12), used by OWCK/OWFCK;
//! * [`Combiner::MembershipMixture`] — membership-probability mixture with
//!   the law-of-total-variance spread (Eq. 13–16), used by GMMCK;
//! * [`Combiner::SingleModel`] — route to one model (§IV-C3), used by MTCK.

/// Per-cluster posterior (mean, variance) pairs at one test point.
#[derive(Debug, Clone, Copy)]
pub struct ClusterPrediction {
    pub mean: f64,
    pub variance: f64,
}

/// Prediction-combination scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Combiner {
    /// Eq. 12: wₗ* ∝ 1/σₗ²; combined variance Σ wₗ²σₗ².
    OptimalWeights,
    /// Eq. 13–16: weights are membership probabilities; the combined
    /// variance uses the mixture (law of total variance) form.
    MembershipMixture,
    /// §IV-C3: use only the routed model's prediction.
    SingleModel,
}

impl Combiner {
    pub fn name(self) -> &'static str {
        match self {
            Combiner::OptimalWeights => "optimal_weights",
            Combiner::MembershipMixture => "membership_mixture",
            Combiner::SingleModel => "single_model",
        }
    }

    /// Combine per-cluster predictions into one posterior.
    ///
    /// `membership_weights` are the Eq. 13 weights (only used by
    /// `MembershipMixture`); `routed` is the single-model choice (only
    /// used by `SingleModel`).
    pub fn combine(
        self,
        preds: &[ClusterPrediction],
        membership_weights: &[f64],
        routed: usize,
    ) -> ClusterPrediction {
        assert!(!preds.is_empty(), "combine: no predictions");
        match self {
            Combiner::OptimalWeights => combine_optimal(preds),
            Combiner::MembershipMixture => combine_mixture(preds, membership_weights),
            Combiner::SingleModel => preds[routed.min(preds.len() - 1)],
        }
    }

    /// Merge a **partial** set of per-cluster posteriors — the distributed
    /// scatter-gather path, where a timed-out or dead shard contributes
    /// nothing and the survivors' weights renormalize.
    ///
    /// `preds[i]` is the posterior of global cluster `cluster_ids[i]`;
    /// `weights` is the FULL k-length membership weight vector (only read
    /// by `MembershipMixture`); `routed` is the globally routed cluster
    /// (only read by `SingleModel`). Every branch funnels into the same
    /// private kernels as [`Self::combine`], so the weight math — the
    /// inverse-variance form, the mixture renormalization and the
    /// [`VAR_FLOOR`] guard — lives in exactly one place:
    ///
    /// * `OptimalWeights` — Eq. 12 over the present subset; the weights
    ///   renormalize by construction (1/σ² over whoever answered).
    /// * `MembershipMixture` — membership weights of the present clusters
    ///   are gathered and renormalized by [`combine_mixture`].
    /// * `SingleModel` — the routed cluster's posterior when its shard
    ///   answered; otherwise degrade to the optimal-weights merge of the
    ///   survivors (an answer with honest variance beats no answer).
    ///
    /// With every cluster present (`cluster_ids == 0..k`, in order) the
    /// result is identical to [`Self::combine`].
    pub fn merge_partial(
        self,
        preds: &[ClusterPrediction],
        cluster_ids: &[usize],
        weights: &[f64],
        routed: usize,
    ) -> ClusterPrediction {
        assert!(!preds.is_empty(), "merge_partial: no predictions");
        assert_eq!(
            preds.len(),
            cluster_ids.len(),
            "merge_partial: prediction/cluster-id mismatch"
        );
        match self {
            Combiner::OptimalWeights => combine_optimal(preds),
            Combiner::MembershipMixture => {
                let w: Vec<f64> =
                    cluster_ids.iter().map(|&c| weights.get(c).copied().unwrap_or(0.0)).collect();
                combine_mixture(preds, &w)
            }
            Combiner::SingleModel => match cluster_ids.iter().position(|&c| c == routed) {
                Some(pos) => preds[pos],
                None => combine_optimal(preds),
            },
        }
    }
}

/// Optimal (minimum-variance) weighting, Eq. 12:
/// wₗ* = (1/σₗ²) / Σᵢ (1/σᵢ²);  mean = Σ wₗ mₗ;  var = Σ wₗ² σₗ².
///
/// Kriging variances can numerically underflow to zero — or dip slightly
/// *negative* — at (near-)interpolated test points, and a raw 1/σₗ²
/// would then produce ±∞/NaN weights. Guarded two ways: a model whose
/// variance is at or below the [`VAR_FLOOR`] is treated as *certain* and
/// dominates (degenerate branch), and the general branch clamps every
/// variance to the floor before inverting so the weights stay finite.
const VAR_FLOOR: f64 = 1e-12;

fn combine_optimal(preds: &[ClusterPrediction]) -> ClusterPrediction {
    // Degenerate branch: any certain (σ² ≤ floor, including negative-
    // underflow) model dominates; average the certain ones.
    let certain: Vec<&ClusterPrediction> =
        preds.iter().filter(|p| p.variance <= VAR_FLOOR).collect();
    if !certain.is_empty() {
        crate::obs::health::counters().note_floor_hit();
        let mean = certain.iter().map(|p| p.mean).sum::<f64>() / certain.len() as f64;
        return ClusterPrediction { mean, variance: 0.0 };
    }
    // General branch: every σ² > floor, but clamp anyway so the invariant
    // is local to this line rather than to the filter above.
    let inv_sum: f64 = preds.iter().map(|p| 1.0 / p.variance.max(VAR_FLOOR)).sum();
    let mut mean = 0.0;
    let mut variance = 0.0;
    for p in preds {
        let v = p.variance.max(VAR_FLOOR);
        let w = (1.0 / v) / inv_sum;
        mean += w * p.mean;
        variance += w * w * v;
    }
    ClusterPrediction { mean, variance }
}

/// Membership-probability mixture, Eq. 15–16:
/// mean = Σ wₗ mₗ;  var = Σ wₗ (σₗ² + mₗ²) − mean².
fn combine_mixture(preds: &[ClusterPrediction], weights: &[f64]) -> ClusterPrediction {
    assert_eq!(preds.len(), weights.len(), "mixture: weight/pred mismatch");
    let wsum: f64 = weights.iter().sum();
    // Degenerate membership (all ~0, e.g. far outside the GMM support):
    // fall back to uniform weights.
    let uniform = 1.0 / preds.len() as f64;
    let norm = |w: f64| if wsum > 1e-12 { w / wsum } else { uniform };
    let mut mean = 0.0;
    for (p, &w) in preds.iter().zip(weights) {
        mean += norm(w) * p.mean;
    }
    let mut second = 0.0;
    for (p, &w) in preds.iter().zip(weights) {
        second += norm(w) * (p.variance + p.mean * p.mean);
    }
    ClusterPrediction { mean, variance: (second - mean * mean).max(0.0) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check_default, gen_size};

    fn p(mean: f64, variance: f64) -> ClusterPrediction {
        ClusterPrediction { mean, variance }
    }

    #[test]
    fn optimal_weights_match_eq12_closed_form() {
        // σ² = [1, 4]: w = [0.8, 0.2].
        let preds = [p(10.0, 1.0), p(20.0, 4.0)];
        let out = Combiner::OptimalWeights.combine(&preds, &[], 0);
        assert!((out.mean - (0.8 * 10.0 + 0.2 * 20.0)).abs() < 1e-12);
        assert!((out.variance - (0.64 * 1.0 + 0.04 * 4.0)).abs() < 1e-12);
    }

    #[test]
    fn optimal_weights_certain_model_dominates() {
        let preds = [p(5.0, 0.0), p(100.0, 1.0)];
        let out = Combiner::OptimalWeights.combine(&preds, &[], 0);
        assert_eq!(out.mean, 5.0);
        assert_eq!(out.variance, 0.0);
    }

    #[test]
    fn optimal_variance_not_above_best_single_prop() {
        // The whole point of Eq. 12: combined variance ≤ min σₗ².
        check_default(|rng| {
            let k = gen_size(rng, 1, 8);
            let preds: Vec<ClusterPrediction> = (0..k)
                .map(|_| p(rng.uniform_in(-5.0, 5.0), rng.uniform_in(0.01, 4.0)))
                .collect();
            let out = Combiner::OptimalWeights.combine(&preds, &[], 0);
            let best = preds.iter().map(|q| q.variance).fold(f64::INFINITY, f64::min);
            crate::prop_assert!(
                out.variance <= best + 1e-12,
                "combined {} > best single {best}",
                out.variance
            );
            Ok(())
        });
    }

    #[test]
    fn optimal_beats_uniform_weighting_prop() {
        // Optimal weights minimize Σw²σ² over the simplex, so they can't
        // lose to uniform weights.
        check_default(|rng| {
            let k = gen_size(rng, 2, 6);
            let preds: Vec<ClusterPrediction> =
                (0..k).map(|_| p(0.0, rng.uniform_in(0.05, 3.0))).collect();
            let out = Combiner::OptimalWeights.combine(&preds, &[], 0);
            let uni = 1.0 / k as f64;
            let uniform_var: f64 = preds.iter().map(|q| uni * uni * q.variance).sum();
            crate::prop_assert!(out.variance <= uniform_var + 1e-12);
            Ok(())
        });
    }

    #[test]
    fn optimal_weights_survive_degenerate_variances() {
        // Subnormal, exactly-zero and negative-underflow variances must
        // never produce NaN/∞ — the certain models dominate and their
        // means average.
        for bad in [0.0, 1e-320, -1e-15, 1e-13] {
            let preds = [p(2.0, bad), p(100.0, 1.0)];
            let out = Combiner::OptimalWeights.combine(&preds, &[], 0);
            assert!(out.mean.is_finite() && out.variance.is_finite(), "σ²={bad}");
            assert_eq!(out.mean, 2.0, "certain model must dominate at σ²={bad}");
            assert_eq!(out.variance, 0.0);
        }
        // Two degenerate models average; the healthy one is ignored.
        let preds = [p(1.0, 0.0), p(3.0, -1e-300), p(50.0, 2.0)];
        let out = Combiner::OptimalWeights.combine(&preds, &[], 0);
        assert_eq!(out.mean, 2.0);
        // Just above the floor stays on the general inverse-variance
        // branch and must still be finite with near-total weight.
        let preds = [p(7.0, 1e-9), p(0.0, 1.0)];
        let out = Combiner::OptimalWeights.combine(&preds, &[], 0);
        assert!(out.mean.is_finite() && out.variance.is_finite());
        assert!((out.mean - 7.0).abs() < 1e-6, "{}", out.mean);
    }

    #[test]
    fn mixture_matches_eq15_16() {
        let preds = [p(1.0, 0.5), p(3.0, 1.0)];
        let w = [0.25, 0.75];
        let out = Combiner::MembershipMixture.combine(&preds, &w, 0);
        let mean = 0.25 * 1.0 + 0.75 * 3.0;
        let second = 0.25 * (0.5 + 1.0) + 0.75 * (1.0 + 9.0);
        assert!((out.mean - mean).abs() < 1e-12);
        assert!((out.variance - (second - mean * mean)).abs() < 1e-12);
    }

    #[test]
    fn mixture_one_hot_recovers_single_model() {
        let preds = [p(1.0, 0.5), p(3.0, 2.0)];
        let out = Combiner::MembershipMixture.combine(&preds, &[0.0, 1.0], 0);
        assert!((out.mean - 3.0).abs() < 1e-12);
        assert!((out.variance - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mixture_variance_includes_disagreement() {
        // Identical variances but different means → mixture variance must
        // exceed the common variance (models disagree).
        let preds = [p(0.0, 1.0), p(10.0, 1.0)];
        let out = Combiner::MembershipMixture.combine(&preds, &[0.5, 0.5], 0);
        assert!(out.variance > 1.0);
        assert!((out.mean - 5.0).abs() < 1e-12);
    }

    #[test]
    fn mixture_degenerate_weights_fall_back_to_uniform() {
        let preds = [p(2.0, 1.0), p(4.0, 1.0)];
        let out = Combiner::MembershipMixture.combine(&preds, &[0.0, 0.0], 0);
        assert!((out.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn single_model_routes() {
        let preds = [p(1.0, 0.1), p(2.0, 0.2), p(3.0, 0.3)];
        let out = Combiner::SingleModel.combine(&preds, &[], 1);
        assert_eq!(out.mean, 2.0);
        assert_eq!(out.variance, 0.2);
        // Out-of-range routing clamps instead of panicking.
        let clamped = Combiner::SingleModel.combine(&preds, &[], 99);
        assert_eq!(clamped.mean, 3.0);
    }

    #[test]
    fn merge_partial_full_presence_matches_combine_prop() {
        // With every cluster present and in order, merge_partial IS
        // combine — bit-identical, all three schemes.
        check_default(|rng| {
            let k = gen_size(rng, 1, 8);
            let preds: Vec<ClusterPrediction> = (0..k)
                .map(|_| p(rng.uniform_in(-5.0, 5.0), rng.uniform_in(0.0, 4.0)))
                .collect();
            let mut weights: Vec<f64> = (0..k).map(|_| rng.uniform_in(0.0, 1.0)).collect();
            let s: f64 = weights.iter().sum();
            if s > 0.0 {
                for w in &mut weights {
                    *w /= s;
                }
            }
            let ids: Vec<usize> = (0..k).collect();
            let routed = gen_size(rng, 0, k - 1);
            for c in
                [Combiner::OptimalWeights, Combiner::MembershipMixture, Combiner::SingleModel]
            {
                let full = c.combine(&preds, &weights, routed);
                let partial = c.merge_partial(&preds, &ids, &weights, routed);
                crate::prop_assert!(
                    full.mean.to_bits() == partial.mean.to_bits()
                        && full.variance.to_bits() == partial.variance.to_bits(),
                    "{}: partial merge diverged from combine",
                    c.name()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn merge_partial_renormalizes_surviving_weights() {
        // Cluster 1 of 3 is missing (dead shard). Mixture weights of the
        // survivors renormalize: [0.2, 0.3] → [0.4, 0.6].
        let preds = [p(1.0, 0.5), p(3.0, 1.0)];
        let out = Combiner::MembershipMixture.merge_partial(
            &preds,
            &[0, 2],
            &[0.2, 0.5, 0.3],
            0,
        );
        let mean = 0.4 * 1.0 + 0.6 * 3.0;
        let second = 0.4 * (0.5 + 1.0) + 0.6 * (1.0 + 9.0);
        assert!((out.mean - mean).abs() < 1e-12);
        assert!((out.variance - (second - mean * mean)).abs() < 1e-12);
        // Optimal weights over the survivors: σ² = [0.5, 1.0] → w = [2/3, 1/3].
        let out = Combiner::OptimalWeights.merge_partial(&preds, &[0, 2], &[], 0);
        let w0 = (1.0 / 0.5) / (1.0 / 0.5 + 1.0);
        assert!((out.mean - (w0 * 1.0 + (1.0 - w0) * 3.0)).abs() < 1e-12);
    }

    #[test]
    fn merge_partial_single_model_degrades_when_routed_missing() {
        let preds = [p(1.0, 0.5), p(3.0, 1.0)];
        // Routed cluster present: its posterior verbatim.
        let out = Combiner::SingleModel.merge_partial(&preds, &[0, 2], &[], 2);
        assert_eq!(out.mean, 3.0);
        assert_eq!(out.variance, 1.0);
        // Routed cluster's shard is dead: optimal-weights fallback over
        // whoever answered — finite, never a panic or a hole.
        let out = Combiner::SingleModel.merge_partial(&preds, &[0, 2], &[], 1);
        let expect = Combiner::OptimalWeights.combine(&preds, &[], 0);
        assert_eq!(out.mean, expect.mean);
        assert_eq!(out.variance, expect.variance);
    }

    #[test]
    fn certain_branch_bumps_floor_counter() {
        // Counters are process-global and tests run concurrently, so
        // assert on the delta with >=.
        let before = crate::obs::health::counters().snapshot();
        let preds = [p(5.0, 0.0), p(100.0, 1.0)];
        let _ = Combiner::OptimalWeights.combine(&preds, &[], 0);
        let delta = crate::obs::health::counters().snapshot().delta_since(&before);
        assert!(delta.combiner_floor_hits >= 1);
    }

    #[test]
    fn names_stable() {
        assert_eq!(Combiner::OptimalWeights.name(), "optimal_weights");
        assert_eq!(Combiner::MembershipMixture.name(), "membership_mixture");
        assert_eq!(Combiner::SingleModel.name(), "single_model");
    }
}
