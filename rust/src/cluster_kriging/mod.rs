//! Cluster Kriging — the paper's framework (§IV) and flavors (§V).
//!
//! Three pluggable stages:
//! 1. **Partitioning** ([`partitioner`]) — k-means, fuzzy C-means, GMM,
//!    regression tree or random;
//! 2. **Modeling** ([`model`]) — one [`crate::kriging::OrdinaryKriging`]
//!    per cluster, hyper-parameters optimized independently, fitted in
//!    parallel;
//! 3. **Prediction** ([`combiner`]) — optimal inverse-variance weights,
//!    membership-probability mixture, or single-model routing.

pub mod builder;
pub mod combiner;
pub mod model;
pub mod partitioner;

pub use builder::{flavor, FLAVORS, PAPER_OVERLAP};
pub use combiner::{ClusterPrediction, Combiner};
pub use model::{ClusterKriging, ClusterKrigingConfig};
pub use partitioner::{
    FcmPartitioner, GmmPartitioner, KMeansPartitioner, Membership, Partition, Partitioner,
    RandomPartitioner, TreePartitioner,
};
