//! Distributed cluster serving: shard a fitted Cluster Kriging ensemble
//! across worker processes and serve it through a scatter-gather
//! coordinator.
//!
//! The paper's decomposition is the whole story here: a k-cluster model
//! is k independent Kriging posteriors plus an **associative** merge —
//! both the inverse-variance optimal weighting (Eq. 12) and the
//! membership mixture (Eq. 15–16) reduce per-cluster `(mean, variance)`
//! pairs, so the merge works just as well over a network as over a
//! `Vec<ClusterPrediction>` (the "aggregate submodel posteriors" view
//! that Nested Kriging formalizes). One `ckrig serve` process is bounded
//! by one machine; k clusters are embarrassingly shardable:
//!
//! ```text
//!                       predictb (client)
//!                             │
//!                  ┌──────────▼──────────┐
//!                  │ coordinator          │  ShardManifest: Membership,
//!                  │ (ShardedClusterKriging│  Combiner, shard→cluster map
//!                  │  + ShardPool)        │
//!                  └──┬───────┬───────┬──┘
//!             spredict│       │       │        (protocol v5, persistent
//!                  ┌──▼──┐ ┌──▼──┐ ┌──▼──┐      connections, deadlines)
//!                  │shard│ │shard│ │shard│    each: ClusterShard artifact
//!                  │  0  │ │  1  │ │  2  │    = its clusters' Kriging
//!                  └─────┘ └─────┘ └─────┘      models + the full oracle
//! ```
//!
//! * [`ClusterShard`] — one worker's slice of the ensemble: a subset of
//!   the per-cluster models plus the **full** serialized
//!   [`crate::cluster_kriging::Membership`], so any node can route. It is
//!   a first-class [`crate::kriging::Surrogate`] (TAG_SHARD artifacts,
//!   observable, servable standalone) whose `spredict` answers carry raw,
//!   *uncombined* [`crate::cluster_kriging::ClusterPrediction`]s.
//! * [`ShardManifest`] — the coordinator's topology + routing state:
//!   shard→cluster assignment, combiner, routing oracle, and the
//!   training-fold standardizer when shards are raw-unit wrapped.
//! * [`ShardedClusterKriging`] — the coordinator-side model: fans a
//!   batch out over a [`crate::coordinator::ShardPool`], merges partial
//!   posteriors through [`crate::cluster_kriging::Combiner::merge_partial`]
//!   (the exact in-process weight math), and degrades gracefully — a
//!   dead or timed-out shard is dropped from the merge with the
//!   survivors' weights renormalized, a `stats`-visible `degraded`
//!   counter ticks, and reconnection retries in the background.
//!   Observations route to the owning shard via `Membership::route`.
//! * [`split_artifact`] — the `ckrig shard` tool: split a fitted
//!   ClusterKriging (or Standardized-wrapped) artifact into per-shard
//!   artifacts + a manifest.

pub mod shard;
pub mod sharded;

pub use shard::{split_artifact, ClusterShard, ShardManifest, SplitOutput};
pub use sharded::ShardedClusterKriging;

use crate::util::matrix::Matrix;

/// Raw per-cluster posterior access — what a shard worker serves over
/// protocol v5 `spredict` and a scatter-gather coordinator merges.
/// Implemented by [`ClusterShard`] (its owned subset), by
/// [`crate::cluster_kriging::ClusterKriging`] (all clusters — the
/// one-shard topology and the equivalence reference), and forwarded by
/// the serving wrappers ([`crate::surrogate::Standardized`],
/// [`crate::online::OnlineModel`]).
pub trait ShardPredictor: Send + Sync {
    /// Global cluster ids this predictor answers for, ascending.
    fn cluster_ids(&self) -> Vec<usize>;

    /// Total cluster count of the (pre-split) ensemble.
    fn k_total(&self) -> usize;

    /// `(shard_index, shard_count)` for a true shard; `None` for a
    /// monolithic ensemble serving all clusters.
    fn shard_index(&self) -> Option<(usize, usize)>;

    /// Per-row raw posteriors: for each row of `xt`, the
    /// `(global_cluster_id, mean, variance)` triple of every owned
    /// cluster — restricted to `filter` when given — in ascending
    /// cluster-id order. Errors when `filter` selects no owned cluster.
    fn predict_clusters(
        &self,
        xt: &Matrix,
        filter: Option<&[usize]>,
    ) -> anyhow::Result<Vec<Vec<(usize, f64, f64)>>>;
}
