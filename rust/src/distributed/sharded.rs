//! [`ShardedClusterKriging`]: the coordinator-side model of a sharded
//! ensemble — scatter a batch over the shard workers, gather raw
//! per-cluster posteriors, merge through the in-process combiner.
//!
//! It is a plain [`Surrogate`], so it slots into the existing serving
//! stack unchanged: the [`crate::coordinator::Batcher`] micro-batches
//! client `predictb` traffic into one `predict_into` call, which this
//! type answers by fanning `spredict` out over a persistent
//! [`ShardPool`] and merging with
//! [`Combiner::merge_partial`][crate::cluster_kriging::Combiner::merge_partial]
//! — the exact weight math the monolithic model uses, which is why a
//! fully-healthy fleet reproduces `ClusterKriging::predict` bit for bit.
//!
//! **Degradation contract:** a dead or timed-out shard contributes
//! nothing to the merge; the survivors' weights renormalize (the
//! combiner's partial-merge semantics), one `degraded` tick lands in the
//! pool/server metrics, and the pool retries the connection in the
//! background. Requests fail only when *no* shard answers. Single-model
//! routing (MTCK) degrades the same way: if the routed cluster's owner
//! is down, the batch falls back to an optimal-weights merge over
//! whoever answers — an answer with honest variance beats no answer.

use crate::cluster_kriging::{ClusterPrediction, Combiner};
use crate::coordinator::ShardPool;
use crate::distributed::ShardManifest;
use crate::kriging::{Prediction, Surrogate};
use crate::obs::trace;
use crate::online::{OnlineObserver, OnlineStats};
use crate::util::matrix::Matrix;
use anyhow::{ensure, Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Coordinator-side scatter-gather model over a pool of shard workers.
pub struct ShardedClusterKriging {
    manifest: ShardManifest,
    pool: Arc<ShardPool>,
    name: String,
    /// Observations routed to owning shards over this model's lifetime.
    observed: AtomicU64,
}

impl ShardedClusterKriging {
    pub fn new(manifest: ShardManifest, pool: Arc<ShardPool>) -> Result<Self> {
        ensure!(
            pool.shard_count() == manifest.shard_count(),
            "pool has {} shards but the manifest expects {}",
            pool.shard_count(),
            manifest.shard_count()
        );
        let name = format!("sharded-{}x{}", manifest.flavor, manifest.shard_count());
        Ok(Self { manifest, pool, name, observed: AtomicU64::new(0) })
    }

    pub fn manifest(&self) -> &ShardManifest {
        &self.manifest
    }

    pub fn pool(&self) -> &Arc<ShardPool> {
        &self.pool
    }

    /// Query points in routing units: the oracle was fitted in
    /// (possibly standardized) fit units, while clients speak raw units.
    /// Returns `None` when they coincide (no standardizer).
    fn routing_view(&self, xt: &Matrix) -> Option<Matrix> {
        self.manifest.standardizer.as_ref().map(|s| s.transform_x(xt))
    }

    /// Weighted-combiner path: one fan-out of the whole batch to every
    /// shard, then a per-row partial merge over whoever answered.
    fn predict_weighted(
        &self,
        xt: &Matrix,
        rxt: &Matrix,
        mean: &mut [f64],
        variance: &mut [f64],
    ) -> Result<()> {
        let k = self.manifest.k_total;
        let results = self.pool.scatter(xt);
        let answered = results.iter().filter(|r| r.is_some()).count();
        ensure!(answered > 0, "no shard answered the prediction fan-out");
        if answered < results.len() {
            self.pool.note_degraded();
        }
        let mut ids: Vec<usize> = Vec::with_capacity(k);
        let mut preds: Vec<ClusterPrediction> = Vec::with_capacity(k);
        let mut pairs: Vec<(usize, f64, f64)> = Vec::with_capacity(k);
        trace::span("combine", || -> Result<()> {
            for i in 0..xt.rows() {
                pairs.clear();
                for shard_rows in results.iter().flatten() {
                    pairs.extend_from_slice(&shard_rows[i]);
                }
                // Ascending cluster order — the monolithic combine iterates
                // models 0..k, and matching its summation order keeps the
                // healthy-fleet result bit-identical.
                pairs.sort_unstable_by_key(|p| p.0);
                // A worker whose slot was hot-swapped behind the pool's back
                // could answer for clusters it doesn't own; a duplicated id
                // would silently double-weight the merge. Served answers must
                // be wrong loudly, not quietly.
                ensure!(
                    pairs.windows(2).all(|w| w[0].0 < w[1].0)
                        && pairs.last().is_none_or(|p| p.0 < k),
                    "shard fan-out returned duplicate or out-of-range cluster ids \
                     (a worker is serving a different topology than the manifest)"
                );
                ids.clear();
                preds.clear();
                for &(c, m, v) in &pairs {
                    ids.push(c);
                    preds.push(ClusterPrediction { mean: m, variance: v });
                }
                let weights = self.manifest.membership.weights(rxt.row(i), k);
                let out = self.manifest.combiner.merge_partial(&preds, &ids, &weights, 0);
                mean[i] = out.mean;
                variance[i] = out.variance;
            }
            Ok(())
        })?;
        Ok(())
    }

    /// Single-model (MTCK) path: group rows by routed cluster — the same
    /// grouping the monolithic batch path uses — and send each group to
    /// the owning shard only, with a cluster filter so the worker
    /// evaluates exactly one model per group.
    fn predict_routed(
        &self,
        xt: &Matrix,
        rxt: &Matrix,
        mean: &mut [f64],
        variance: &mut [f64],
    ) -> Result<()> {
        let k = self.manifest.k_total;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); k];
        for i in 0..xt.rows() {
            groups[self.manifest.membership.route(rxt.row(i)).min(k - 1)].push(i);
        }
        let mut dropped = false;
        for (ci, rows) in groups.iter().enumerate() {
            if rows.is_empty() {
                continue;
            }
            let sub = xt.select_rows(rows);
            let owner = self.manifest.owner_of(ci);
            let only = [ci];
            match self.pool.shard_predict(owner, &sub, Some(&only[..])) {
                Ok(partials) => {
                    for (local, &row) in rows.iter().enumerate() {
                        let &(got, m, v) = partials
                            .get(local)
                            .and_then(|e| e.first())
                            .context("shard returned a short spredict reply")?;
                        ensure!(
                            got == ci,
                            "shard {owner} answered cluster {got} for a cluster-{ci} request"
                        );
                        mean[row] = m;
                        variance[row] = v;
                    }
                }
                Err(e) => {
                    // The routed owner is down: degrade this group to an
                    // optimal-weights merge over the surviving shards.
                    dropped = true;
                    log::warn!(
                        "shard {owner} unavailable for routed cluster {ci} ({e:#}); \
                         merging survivors"
                    );
                    let results = self.pool.scatter(&sub);
                    let mut pairs: Vec<(usize, f64, f64)> = Vec::with_capacity(k);
                    for (local, &row) in rows.iter().enumerate() {
                        pairs.clear();
                        for shard_rows in results.iter().flatten() {
                            pairs.extend_from_slice(&shard_rows[local]);
                        }
                        ensure!(
                            !pairs.is_empty(),
                            "no shard answered for routed cluster {ci}"
                        );
                        pairs.sort_unstable_by_key(|p| p.0);
                        let ids: Vec<usize> = pairs.iter().map(|p| p.0).collect();
                        let preds: Vec<ClusterPrediction> = pairs
                            .iter()
                            .map(|&(_, m, v)| ClusterPrediction { mean: m, variance: v })
                            .collect();
                        // `routed = ci` is absent from `ids` (its owner is
                        // down), so merge_partial takes its degraded
                        // optimal-weights branch.
                        let out = self.manifest.combiner.merge_partial(&preds, &ids, &[], ci);
                        mean[row] = out.mean;
                        variance[row] = out.variance;
                    }
                }
            }
        }
        if dropped {
            self.pool.note_degraded();
        }
        Ok(())
    }
}

impl Surrogate for ShardedClusterKriging {
    fn predict(&self, xt: &Matrix) -> Result<Prediction> {
        let mut mean = vec![0.0; xt.rows()];
        let mut variance = vec![0.0; xt.rows()];
        self.predict_into(xt, &mut mean, &mut variance)?;
        Ok(Prediction { mean, variance })
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn dim(&self) -> usize {
        self.manifest.dim
    }

    fn predict_into(&self, xt: &Matrix, mean: &mut [f64], variance: &mut [f64]) -> Result<()> {
        ensure!(
            xt.cols() == self.manifest.dim,
            "predict: points have {} dims, sharded model expects {}",
            xt.cols(),
            self.manifest.dim
        );
        let routing = self.routing_view(xt);
        let rxt = routing.as_ref().unwrap_or(xt);
        match self.manifest.combiner {
            Combiner::SingleModel => self.predict_routed(xt, rxt, mean, variance)?,
            _ => self.predict_weighted(xt, rxt, mean, variance)?,
        }
        // Standardized shards answer `spredict` in fit units (see the
        // `ShardPredictor` impl on `Standardized`): the merge above ran in
        // the same units the monolithic model combines in — variance
        // floor included — and only the *combined* posterior converts
        // back to raw units, exactly as `Standardized::predict_into`
        // does. Bit-identical to the unsharded artifact.
        if let Some(std) = &self.manifest.standardizer {
            for m in mean.iter_mut() {
                *m = std.inverse_y(*m);
            }
            for v in variance.iter_mut() {
                *v = std.inverse_var(*v);
            }
        }
        Ok(())
    }

    fn observer(&self) -> Option<&dyn OnlineObserver> {
        Some(self)
    }
}

impl OnlineObserver for ShardedClusterKriging {
    /// Route each observation to the shard owning its
    /// `Membership::route` cluster and forward it over the wire — the
    /// cluster-local O(n_c²) update happens *on the worker*, so streams
    /// scale with the fleet exactly like predictions do. Groups destined
    /// for different shards are independent: on a shard failure the
    /// other groups still absorb, and the error reports how many
    /// observations landed.
    fn observe_batch(&self, xs: &Matrix, ys: &[f64]) -> Result<()> {
        ensure!(
            xs.cols() == self.manifest.dim,
            "observe: points have {} dims, sharded model expects {}",
            xs.cols(),
            self.manifest.dim
        );
        ensure!(
            xs.rows() == ys.len(),
            "observe: {} points but {} targets",
            xs.rows(),
            ys.len()
        );
        let routing = self.routing_view(xs);
        let rxs = routing.as_ref().unwrap_or(xs);
        let k = self.manifest.k_total;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.manifest.shard_count()];
        for i in 0..xs.rows() {
            let routed = self.manifest.membership.route(rxs.row(i)).min(k - 1);
            groups[self.manifest.owner_of(routed)].push(i);
        }
        let mut absorbed = 0usize;
        let mut failure: Option<anyhow::Error> = None;
        for (shard, rows) in groups.iter().enumerate() {
            if rows.is_empty() {
                continue;
            }
            let sub = xs.select_rows(rows);
            let sys: Vec<f64> = rows.iter().map(|&i| ys[i]).collect();
            match self.pool.observe_rows(shard, &sub, &sys) {
                Ok(n) => absorbed += n,
                Err(e) => {
                    failure.get_or_insert(e.context(format!("shard {shard} observe failed")));
                }
            }
        }
        self.observed.fetch_add(absorbed as u64, Ordering::Relaxed);
        match failure {
            None => Ok(()),
            Some(e) => {
                Err(e.context(format!("absorbed {absorbed} of {} observations", ys.len())))
            }
        }
    }

    fn online_stats(&self) -> OnlineStats {
        OnlineStats {
            observed: self.observed.load(Ordering::Relaxed),
            ..Default::default()
        }
    }
}
