//! Shard artifacts: splitting a fitted [`ClusterKriging`] into
//! per-worker [`ClusterShard`]s plus a coordinator [`ShardManifest`].
//!
//! A shard is a *complete, servable model*: its slice of the per-cluster
//! Kriging models (factors included) plus the **full** routing oracle,
//! so any node can route an observation to the owning cluster and a
//! shard server can answer standalone predictions (partially, over its
//! own clusters) if asked directly. Both artifact kinds reuse the CKRG
//! container (v3): `TAG_SHARD` loads back through the one
//! [`crate::surrogate::SurrogateSpec::load`] dispatch like every other
//! model, `TAG_SHARD_MANIFEST` is deliberately *not* servable and loads
//! through [`ShardManifest::load`] only.
//!
//! Cluster→shard assignment is round-robin (`cluster c → shard c mod S`),
//! so cluster sizes balance without a packing pass and ownership is
//! computable from the id alone.

use crate::cluster_kriging::combiner::ClusterPrediction;
use crate::cluster_kriging::model::dedup_snapshot;
use crate::cluster_kriging::{ClusterKriging, Combiner, Membership};
use crate::data::Standardizer;
use crate::distributed::ShardPredictor;
use crate::kriging::{OrdinaryKriging, Prediction, Surrogate};
use crate::surrogate::artifact;
use crate::util::binio::{BinReader, BinWriter};
use crate::util::matrix::Matrix;
use crate::util::threadpool::{default_workers, scoped_map};
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

/// One worker's slice of a split Cluster Kriging ensemble.
pub struct ClusterShard {
    shard_index: usize,
    shard_count: usize,
    /// Global cluster ids owned by this shard, ascending; parallel to
    /// `models`.
    cluster_ids: Vec<usize>,
    models: Vec<OrdinaryKriging>,
    membership: Membership,
    combiner: Combiner,
    flavor: String,
    /// Cached display name ("OWCK[2/4]").
    name: String,
    dim: usize,
    k_total: usize,
    /// Per-owned-cluster training sizes (diagnostics).
    pub cluster_sizes: Vec<usize>,
}

impl ClusterShard {
    /// Split a fitted ensemble into `shard_count` shards, round-robin by
    /// cluster id. Each shard receives its own deep copy of the routing
    /// oracle. Shard workers then serve one shard each; the matching
    /// [`ShardManifest`] (built **before** this consumes the model) is
    /// what a coordinator boots from.
    pub fn split(model: ClusterKriging, shard_count: usize) -> Result<Vec<ClusterShard>> {
        let k = model.k();
        ensure!(shard_count >= 1, "shard count must be ≥ 1");
        ensure!(
            shard_count <= k,
            "cannot split {k} clusters across {shard_count} shards (empty shards)"
        );
        let (models, membership, combiner, flavor, dim, cluster_sizes) = model.into_parts();
        let mut per_shard: Vec<(Vec<usize>, Vec<OrdinaryKriging>, Vec<usize>)> =
            (0..shard_count).map(|_| (Vec::new(), Vec::new(), Vec::new())).collect();
        for (ci, m) in models.into_iter().enumerate() {
            let s = ci % shard_count;
            per_shard[s].0.push(ci);
            per_shard[s].1.push(m);
            per_shard[s].2.push(cluster_sizes[ci]);
        }
        let mut shards = Vec::with_capacity(shard_count);
        for (s, (cluster_ids, models, cluster_sizes)) in per_shard.into_iter().enumerate() {
            // Every shard carries a bit-identical deep copy of the full
            // routing oracle — "any node can route".
            let membership = membership.deep_clone();
            shards.push(ClusterShard {
                shard_index: s,
                shard_count,
                name: format!("{flavor}[{s}/{shard_count}]"),
                cluster_ids,
                models,
                membership,
                combiner,
                flavor: flavor.clone(),
                dim,
                k_total: k,
                cluster_sizes,
            });
        }
        Ok(shards)
    }

    pub fn shard(&self) -> (usize, usize) {
        (self.shard_index, self.shard_count)
    }

    pub fn owned_clusters(&self) -> &[usize] {
        &self.cluster_ids
    }

    pub fn k_total(&self) -> usize {
        self.k_total
    }

    pub fn flavor(&self) -> &str {
        &self.flavor
    }

    /// Absorb one observation into the owned cluster `Membership::route`
    /// picks — identical arithmetic to
    /// [`ClusterKriging::observe_point`], restricted to ownership: a
    /// point routed to a cluster another shard owns is a recoverable
    /// error naming the owner, so a coordinator (or operator) can
    /// redirect it.
    pub fn observe_point(&mut self, x: &[f64], y: f64) -> Result<()> {
        if x.len() != self.dim {
            bail!("observe: point has {} dims, shard expects {}", x.len(), self.dim);
        }
        let routed = self.membership.route(x).min(self.k_total - 1);
        match self.cluster_ids.binary_search(&routed) {
            Ok(pos) => {
                self.models[pos]
                    .observe_point(x, y)
                    .with_context(|| format!("cluster {routed} observe failed"))?;
                self.cluster_sizes[pos] += 1;
                Ok(())
            }
            Err(_) => bail!(
                "point routes to cluster {routed}, owned by shard {} (this is shard {}/{})",
                routed % self.shard_count,
                self.shard_index,
                self.shard_count
            ),
        }
    }

    pub(crate) fn write_artifact(&self, w: &mut BinWriter) {
        w.put_str(&self.flavor);
        w.put_u8(match self.combiner {
            Combiner::OptimalWeights => 0,
            Combiner::MembershipMixture => 1,
            Combiner::SingleModel => 2,
        });
        w.put_usize(self.dim);
        w.put_usize(self.k_total);
        w.put_usize(self.shard_index);
        w.put_usize(self.shard_count);
        w.put_usize_slice(&self.cluster_ids);
        w.put_usize_slice(&self.cluster_sizes);
        w.put_usize(self.models.len());
        for m in &self.models {
            m.write_artifact(w);
        }
        self.membership.write_artifact(w);
    }

    pub(crate) fn read_artifact(r: &mut BinReader<'_>, version: u32) -> Result<Self> {
        let flavor = r.get_str()?;
        let combiner = match r.get_u8()? {
            0 => Combiner::OptimalWeights,
            1 => Combiner::MembershipMixture,
            2 => Combiner::SingleModel,
            other => bail!("unknown combiner tag {other}"),
        };
        let dim = r.get_usize()?;
        let k_total = r.get_usize()?;
        let shard_index = r.get_usize()?;
        let shard_count = r.get_usize()?;
        let cluster_ids = r.get_usize_vec()?;
        let cluster_sizes = r.get_usize_vec()?;
        let n_models = r.get_usize()?;
        ensure!(
            shard_count >= 1 && shard_index < shard_count,
            "shard artifact index {shard_index} out of range for {shard_count} shards"
        );
        ensure!(
            n_models == cluster_ids.len() && n_models == cluster_sizes.len() && n_models >= 1,
            "shard artifact model/cluster-id count mismatch"
        );
        ensure!(
            cluster_ids.windows(2).all(|w| w[0] < w[1])
                && cluster_ids.iter().all(|&c| c < k_total),
            "shard artifact cluster ids not ascending in 0..{k_total}"
        );
        let mut models = Vec::with_capacity(n_models);
        for _ in 0..n_models {
            let m = OrdinaryKriging::read_artifact(r, version)?;
            ensure!(
                crate::kriging::Surrogate::dim(&m) == dim,
                "per-cluster model dimension disagrees with shard"
            );
            models.push(m);
        }
        let membership = Membership::read_artifact(r)?;
        Ok(Self {
            name: format!("{flavor}[{shard_index}/{shard_count}]"),
            shard_index,
            shard_count,
            cluster_ids,
            models,
            membership,
            combiner,
            flavor,
            dim,
            k_total,
            cluster_sizes,
        })
    }
}

/// Per-row raw posteriors for a subset of a cluster set: every model in
/// `models` (global ids in `ids`, ascending, selected down to `filter`)
/// batch-predicts the whole `xt` in parallel — the same
/// one-worker-per-model arithmetic as the in-process weighted predict
/// path, so a scatter-gather merge reproduces it bit for bit.
fn predict_cluster_subset(
    models: &[OrdinaryKriging],
    ids: &[usize],
    xt: &Matrix,
    filter: Option<&[usize]>,
) -> Result<Vec<Vec<(usize, f64, f64)>>> {
    let selected: Vec<usize> = match filter {
        None => (0..models.len()).collect(),
        Some(f) => (0..models.len()).filter(|&i| f.contains(&ids[i])).collect(),
    };
    ensure!(
        !selected.is_empty(),
        "no requested cluster is owned here (owned {:?}, requested {:?})",
        ids,
        filter.unwrap_or(&[])
    );
    // Per-cluster predicts run on scoped worker threads; hand the calling
    // thread's ambient trace context across so the models' kernel-
    // assembly / triangular-solve spans land in the request's tree.
    let ctx = crate::obs::trace::current();
    let per_model: Vec<Result<Prediction>> = scoped_map(&selected, default_workers(), |_, &i| {
        let _guard = ctx.clone().map(crate::obs::trace::enter);
        // One assembly worker per model: the map above already
        // parallelizes across the selected models.
        models[i]
            .predict_with_workers(xt, 1)
            .with_context(|| format!("cluster {} predict failed", ids[i]))
    });
    let mut out = vec![Vec::with_capacity(selected.len()); xt.rows()];
    for (slot, pred) in selected.iter().zip(per_model) {
        let pred = pred?;
        for (row, entries) in out.iter_mut().enumerate() {
            entries.push((ids[*slot], pred.mean[row], pred.variance[row]));
        }
    }
    Ok(out)
}

impl ShardPredictor for ClusterShard {
    fn cluster_ids(&self) -> Vec<usize> {
        self.cluster_ids.clone()
    }

    fn k_total(&self) -> usize {
        self.k_total
    }

    fn shard_index(&self) -> Option<(usize, usize)> {
        Some((self.shard_index, self.shard_count))
    }

    fn predict_clusters(
        &self,
        xt: &Matrix,
        filter: Option<&[usize]>,
    ) -> Result<Vec<Vec<(usize, f64, f64)>>> {
        ensure!(
            xt.cols() == self.dim,
            "spredict: points have {} dims, shard expects {}",
            xt.cols(),
            self.dim
        );
        predict_cluster_subset(&self.models, &self.cluster_ids, xt, filter)
    }
}

impl ShardPredictor for ClusterKriging {
    fn cluster_ids(&self) -> Vec<usize> {
        (0..self.k()).collect()
    }

    fn k_total(&self) -> usize {
        self.k()
    }

    fn shard_index(&self) -> Option<(usize, usize)> {
        None
    }

    fn predict_clusters(
        &self,
        xt: &Matrix,
        filter: Option<&[usize]>,
    ) -> Result<Vec<Vec<(usize, f64, f64)>>> {
        ensure!(
            xt.cols() == crate::kriging::Surrogate::dim(self),
            "spredict: points have {} dims, model expects {}",
            xt.cols(),
            crate::kriging::Surrogate::dim(self)
        );
        let ids: Vec<usize> = (0..self.k()).collect();
        predict_cluster_subset(self.models(), &ids, xt, filter)
    }
}

impl Surrogate for ClusterShard {
    fn predict(&self, xt: &Matrix) -> Result<Prediction> {
        let mut mean = vec![0.0; xt.rows()];
        let mut variance = vec![0.0; xt.rows()];
        self.predict_into(xt, &mut mean, &mut variance)?;
        Ok(Prediction { mean, variance })
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn dim(&self) -> usize {
        self.dim
    }

    /// Standalone shard predictions merge the *owned* posteriors with
    /// renormalized weights — an honest partial view (exactly what a
    /// degraded coordinator would compute from this shard alone).
    fn predict_into(&self, xt: &Matrix, mean: &mut [f64], variance: &mut [f64]) -> Result<()> {
        let partials = self.predict_clusters(xt, None)?;
        for (i, entries) in partials.iter().enumerate() {
            let preds: Vec<ClusterPrediction> = entries
                .iter()
                .map(|&(_, m, v)| ClusterPrediction { mean: m, variance: v })
                .collect();
            let weights = self.membership.weights(xt.row(i), self.k_total);
            let routed = self.membership.route(xt.row(i)).min(self.k_total - 1);
            let out = self.combiner.merge_partial(&preds, &self.cluster_ids, &weights, routed);
            mean[i] = out.mean;
            variance[i] = out.variance;
        }
        Ok(())
    }

    fn shard_predictor(&self) -> Option<&dyn ShardPredictor> {
        Some(self)
    }

    fn health_report(&self) -> Option<crate::obs::health::HealthReport> {
        // Report under GLOBAL cluster ids so a coordinator can aggregate
        // shard reports without an id collision.
        let clusters = self
            .cluster_ids
            .iter()
            .zip(&self.models)
            .map(|(&cid, m)| crate::obs::health::ClusterHealth {
                cluster: cid,
                health: m.health_or_probe(),
            })
            .collect();
        Some(crate::obs::health::HealthReport { clusters })
    }

    fn as_online(&self) -> Option<&dyn crate::online::OnlineSurrogate> {
        Some(self)
    }

    fn as_online_mut(&mut self) -> Option<&mut dyn crate::online::OnlineSurrogate> {
        Some(self)
    }

    fn save(&self, w: &mut dyn std::io::Write) -> Result<()> {
        let mut payload = BinWriter::new();
        self.write_artifact(&mut payload);
        artifact::write_model(w, artifact::TAG_SHARD, &payload.into_bytes())
    }
}

impl crate::online::OnlineSurrogate for ClusterShard {
    fn observe(&mut self, x: &[f64], y: f64) -> Result<()> {
        self.observe_point(x, y)
    }

    fn training_snapshot(&self) -> (Matrix, Vec<f64>) {
        dedup_snapshot(&self.models, self.dim)
    }
}

/// Coordinator-side topology + routing state for one sharded ensemble.
pub struct ShardManifest {
    pub flavor: String,
    pub combiner: Combiner,
    pub dim: usize,
    pub k_total: usize,
    /// Cluster ids per shard index (round-robin assignment).
    pub shards: Vec<Vec<usize>>,
    pub membership: Membership,
    /// Present when the shard artifacts are [`Standardized`]-wrapped: the
    /// routing oracle lives in fit (standardized) units, so the
    /// coordinator standardizes a raw-unit query before routing; the
    /// shards' answers already come back in raw units.
    pub standardizer: Option<Standardizer>,
}

impl ShardManifest {
    /// Build the manifest for splitting `model` into `shard_count`
    /// shards. Call **before** [`ClusterShard::split`] consumes the
    /// model; the round-robin assignment here is the one `split` applies.
    pub fn from_model(
        model: &ClusterKriging,
        shard_count: usize,
        standardizer: Option<Standardizer>,
    ) -> Result<Self> {
        let k = model.k();
        ensure!(
            shard_count >= 1 && shard_count <= k,
            "cannot split {k} clusters across {shard_count} shards"
        );
        if let Some(s) = &standardizer {
            ensure!(
                s.x_mean.len() == crate::kriging::Surrogate::dim(model),
                "standardizer/model dimension mismatch"
            );
        }
        let mut shards = vec![Vec::new(); shard_count];
        for c in 0..k {
            shards[c % shard_count].push(c);
        }
        Ok(Self {
            flavor: model.flavor().to_string(),
            combiner: model.combiner(),
            dim: crate::kriging::Surrogate::dim(model),
            k_total: k,
            shards,
            membership: model.membership().deep_clone(),
            standardizer,
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard index owning a global cluster id (round-robin).
    pub fn owner_of(&self, cluster: usize) -> usize {
        cluster % self.shards.len()
    }

    pub fn save(&self, w: &mut dyn std::io::Write) -> Result<()> {
        let mut p = BinWriter::new();
        p.put_str(&self.flavor);
        p.put_u8(match self.combiner {
            Combiner::OptimalWeights => 0,
            Combiner::MembershipMixture => 1,
            Combiner::SingleModel => 2,
        });
        p.put_usize(self.dim);
        p.put_usize(self.k_total);
        p.put_usize(self.shards.len());
        for s in &self.shards {
            p.put_usize_slice(s);
        }
        self.membership.write_artifact(&mut p);
        match &self.standardizer {
            None => p.put_bool(false),
            Some(s) => {
                p.put_bool(true);
                p.put_f64_slice(&s.x_mean);
                p.put_f64_slice(&s.x_std);
                p.put_f64(s.y_mean);
                p.put_f64(s.y_std);
            }
        }
        artifact::write_model(w, artifact::TAG_SHARD_MANIFEST, &p.into_bytes())
    }

    pub fn save_to_path(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        crate::util::fsio::atomic_write(path, |w| {
            self.save(w)
                .with_context(|| format!("serializing manifest {}", path.display()))
        })?;
        Ok(())
    }

    pub fn load(mut r: impl std::io::Read) -> Result<Self> {
        let (_version, tag, payload) = artifact::read_model(&mut r)?;
        ensure!(
            tag == artifact::TAG_SHARD_MANIFEST,
            "not a shard manifest (found a {} artifact)",
            artifact::tag_name(tag)
        );
        let mut p = BinReader::new(&payload);
        let flavor = p.get_str()?;
        let combiner = match p.get_u8()? {
            0 => Combiner::OptimalWeights,
            1 => Combiner::MembershipMixture,
            2 => Combiner::SingleModel,
            other => bail!("unknown combiner tag {other}"),
        };
        let dim = p.get_usize()?;
        let k_total = p.get_usize()?;
        let shard_count = p.get_usize()?;
        ensure!(
            shard_count >= 1 && shard_count <= k_total && k_total >= 1,
            "manifest topology inconsistent ({shard_count} shards, {k_total} clusters)"
        );
        let mut shards = Vec::with_capacity(shard_count);
        for _ in 0..shard_count {
            shards.push(p.get_usize_vec()?);
        }
        let mut seen = vec![false; k_total];
        for (s, ids) in shards.iter().enumerate() {
            for &c in ids {
                ensure!(c < k_total && !seen[c], "manifest shard {s} repeats cluster {c}");
                seen[c] = true;
            }
        }
        ensure!(seen.iter().all(|&s| s), "manifest does not cover every cluster");
        let membership = Membership::read_artifact(&mut p)?;
        let standardizer = if p.get_bool()? {
            let x_mean = p.get_f64_vec()?;
            let x_std = p.get_f64_vec()?;
            let y_mean = p.get_f64()?;
            let y_std = p.get_f64()?;
            ensure!(
                x_mean.len() == dim && x_std.len() == dim,
                "manifest standardizer dimension mismatch"
            );
            Some(Standardizer { x_mean, x_std, y_mean, y_std })
        } else {
            None
        };
        Ok(Self { flavor, combiner, dim, k_total, shards, membership, standardizer })
    }

    pub fn load_path(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening manifest {}", path.display()))?;
        Self::load(std::io::BufReader::new(file))
            .with_context(|| format!("loading manifest {}", path.display()))
    }
}

/// What [`split_artifact`] wrote.
pub struct SplitOutput {
    pub manifest_path: PathBuf,
    pub shard_paths: Vec<PathBuf>,
    /// Cluster ids per shard, in shard-index order.
    pub assignment: Vec<Vec<usize>>,
}

/// The `ckrig shard` tool: split a fitted Cluster Kriging artifact
/// (plain, or [`Standardized`]-wrapped as `ckrig fit` writes them) into
/// `shard_count` per-worker shard artifacts plus a coordinator manifest
/// under `out_dir`. Standardized inputs yield Standardized-wrapped
/// shards (each carries the standardizer copy) and a manifest that
/// standardizes before routing — raw-unit queries stay raw-unit end to
/// end.
pub fn split_artifact(
    path: impl AsRef<Path>,
    shard_count: usize,
    out_dir: impl AsRef<Path>,
) -> Result<SplitOutput> {
    use crate::surrogate::Standardized;
    let path = path.as_ref();
    let out_dir = out_dir.as_ref();
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening artifact {}", path.display()))?;
    let (version, tag, payload) = artifact::read_model(&mut std::io::BufReader::new(file))
        .with_context(|| format!("reading artifact {}", path.display()))?;

    let (model, standardizer) = match tag {
        artifact::TAG_CLUSTER_KRIGING => {
            (ClusterKriging::read_artifact(&mut BinReader::new(&payload), version)?, None)
        }
        artifact::TAG_STANDARDIZED => {
            let mut r = BinReader::new(&payload);
            let (std, nested) = Standardized::read_parts(&mut r)?;
            let (nested_version, nested_tag, nested_payload) =
                artifact::read_model(&mut std::io::Cursor::new(nested))?;
            ensure!(
                nested_tag == artifact::TAG_CLUSTER_KRIGING,
                "only Cluster Kriging artifacts can be sharded; this Standardized artifact \
                 wraps a {} model",
                artifact::tag_name(nested_tag)
            );
            let mut nested_reader = BinReader::new(&nested_payload);
            let ck = ClusterKriging::read_artifact(&mut nested_reader, nested_version)?;
            (ck, Some(std))
        }
        other => bail!(
            "only Cluster Kriging artifacts can be sharded (found a {} artifact)",
            artifact::tag_name(other)
        ),
    };

    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let manifest = ShardManifest::from_model(&model, shard_count, standardizer.clone())?;
    let assignment = manifest.shards.clone();
    let shards = ClusterShard::split(model, shard_count)?;
    let mut shard_paths = Vec::with_capacity(shards.len());
    for shard in shards {
        let (idx, _) = shard.shard();
        let shard_path = out_dir.join(format!("shard-{idx}.ck"));
        let model: Box<dyn Surrogate> = match &standardizer {
            Some(std) => Box::new(Standardized::new(Box::new(shard), std.clone())),
            None => Box::new(shard),
        };
        crate::surrogate::save_to_path(model.as_ref(), &shard_path)?;
        shard_paths.push(shard_path);
    }
    let manifest_path = out_dir.join("manifest.ck");
    manifest.save_to_path(&manifest_path)?;
    Ok(SplitOutput { manifest_path, shard_paths, assignment })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster_kriging::builder;
    use crate::kriging::hyperopt::NuggetMode;
    use crate::kriging::HyperOpt;
    use crate::util::proptest::gen_matrix;
    use crate::util::rng::Rng;

    fn fitted(flavor: &str, k: usize, n: usize, seed: u64) -> (ClusterKriging, Matrix) {
        let mut rng = Rng::new(seed);
        let x = gen_matrix(&mut rng, n, 2, -3.0, 3.0);
        let y: Vec<f64> =
            (0..n).map(|i| x.row(i)[0].sin() + 0.3 * x.row(i)[1] * x.row(i)[1]).collect();
        let opt = HyperOpt {
            restarts: 1,
            max_evals: 10,
            isotropic: true,
            nugget: NuggetMode::Fixed(1e-8),
            ..HyperOpt::default()
        };
        let cfg = builder::flavor(flavor, k, seed, opt).unwrap();
        let model = ClusterKriging::fit(&x, &y, cfg).unwrap();
        let probe = gen_matrix(&mut rng, 16, 2, -3.0, 3.0);
        (model, probe)
    }

    #[test]
    fn split_covers_all_clusters_round_robin() {
        let (model, _) = fitted("OWCK", 5, 120, 1);
        let k = model.k();
        let shards = ClusterShard::split(model, 2).unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].owned_clusters(), &[0, 2, 4]);
        assert_eq!(shards[1].owned_clusters(), &[1, 3]);
        for s in &shards {
            assert_eq!(s.k_total(), k);
        }
        // Splitting into more shards than clusters is rejected.
        let (model, _) = fitted("OWCK", 2, 80, 2);
        assert!(ClusterShard::split(model, 3).is_err());
    }

    #[test]
    fn shard_partials_match_monolithic_models() {
        let (model, probe) = fitted("OWCK", 4, 120, 3);
        // Reference: the monolithic ensemble's own raw per-cluster view.
        let reference = model.predict_clusters(&probe, None).unwrap();
        let shards = {
            let (m2, _) = fitted("OWCK", 4, 120, 3); // identical fit (same seed)
            ClusterShard::split(m2, 2).unwrap()
        };
        for shard in &shards {
            let partials = shard.predict_clusters(&probe, None).unwrap();
            for (row, entries) in partials.iter().enumerate() {
                for &(cid, mean, var) in entries {
                    let (_, rm, rv) = reference[row]
                        .iter()
                        .copied()
                        .find(|&(c, _, _)| c == cid)
                        .expect("reference covers every cluster");
                    assert_eq!(mean.to_bits(), rm.to_bits(), "row {row} cluster {cid} mean");
                    assert_eq!(var.to_bits(), rv.to_bits(), "row {row} cluster {cid} var");
                }
            }
        }
        // The cluster filter narrows the answer to the requested subset.
        let only = shards[0].owned_clusters()[0];
        let filtered = shards[0].predict_clusters(&probe, Some(&[only])).unwrap();
        assert!(filtered.iter().all(|e| e.len() == 1 && e[0].0 == only));
        // Filtering for a cluster the shard doesn't own is an error.
        let foreign = shards[1].owned_clusters()[0];
        assert!(shards[0].predict_clusters(&probe, Some(&[foreign])).is_err());
    }

    #[test]
    fn shard_artifact_roundtrips_bit_identically() {
        let (model, probe) = fitted("MTCK", 4, 100, 5);
        let shards = ClusterShard::split(model, 2).unwrap();
        for shard in shards {
            let before = shard.predict_clusters(&probe, None).unwrap();
            let mut bytes = Vec::new();
            shard.save(&mut bytes).unwrap();
            let loaded = crate::surrogate::SurrogateSpec::load(bytes.as_slice()).unwrap();
            let sp = loaded.shard_predictor().expect("loaded shard keeps spredict");
            assert_eq!(sp.shard_index(), shard.shard_index());
            assert_eq!(sp.cluster_ids(), shard.owned_clusters());
            assert_eq!(sp.k_total(), shard.k_total());
            let after = sp.predict_clusters(&probe, None).unwrap();
            for (a, b) in before.iter().zip(&after) {
                for (&(ca, ma, va), &(cb, mb, vb)) in a.iter().zip(b) {
                    assert_eq!(ca, cb);
                    assert_eq!(ma.to_bits(), mb.to_bits());
                    assert_eq!(va.to_bits(), vb.to_bits());
                }
            }
            // And it stays observable through the generic online path.
            let mut loaded = loaded;
            assert!(loaded.as_online_mut().is_some());
        }
    }

    #[test]
    fn shard_observe_routes_and_rejects_foreign_points() {
        let (model, _) = fitted("OWCK", 4, 120, 7);
        let mut rng = Rng::new(8);
        // Each shard owns exactly one cluster; across many probes every
        // point must be accepted by exactly one shard, mentioning the
        // owner in the other shards' errors.
        let mut shards = ClusterShard::split(model, 4).unwrap();
        for _ in 0..20 {
            let p = [rng.uniform_in(-3.0, 3.0), rng.uniform_in(-3.0, 3.0)];
            let mut accepted = 0;
            for s in shards.iter_mut() {
                match s.observe_point(&p, 0.5) {
                    Ok(()) => accepted += 1,
                    Err(e) => {
                        assert!(e.to_string().contains("owned by shard"), "{e:#}")
                    }
                }
            }
            assert_eq!(accepted, 1, "each point must have exactly one owner");
        }
        // Dimension mismatch is recoverable.
        assert!(shards[0].observe_point(&[1.0], 0.0).is_err());
    }

    #[test]
    fn manifest_roundtrips_and_validates() {
        let (model, probe) = fitted("GMMCK", 3, 100, 9);
        let manifest = ShardManifest::from_model(&model, 2, None).unwrap();
        assert_eq!(manifest.shards, vec![vec![0, 2], vec![1]]);
        assert_eq!(manifest.owner_of(2), 0);
        let mut bytes = Vec::new();
        manifest.save(&mut bytes).unwrap();
        let back = ShardManifest::load(bytes.as_slice()).unwrap();
        assert_eq!(back.flavor, manifest.flavor);
        assert_eq!(back.combiner, manifest.combiner);
        assert_eq!(back.k_total, manifest.k_total);
        assert_eq!(back.shards, manifest.shards);
        assert!(back.standardizer.is_none());
        // The routing oracle survives bit-identically.
        for i in 0..probe.rows() {
            let x = probe.row(i);
            assert_eq!(back.membership.route(x), manifest.membership.route(x));
            let a = back.membership.weights(x, back.k_total);
            let b = manifest.membership.weights(x, manifest.k_total);
            for (wa, wb) in a.iter().zip(&b) {
                assert_eq!(wa.to_bits(), wb.to_bits());
            }
        }
        // A manifest is not a servable model.
        let err = crate::surrogate::SurrogateSpec::load(bytes.as_slice()).unwrap_err();
        assert!(err.to_string().contains("manifest"), "{err:#}");
        // A model artifact is not a manifest.
        let mut model_bytes = Vec::new();
        Surrogate::save(&model, &mut model_bytes).unwrap();
        assert!(ShardManifest::load(model_bytes.as_slice()).is_err());
    }

    #[test]
    fn split_artifact_tool_handles_plain_and_standardized() {
        let dir = std::env::temp_dir().join(format!("ckrig_split_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (model, probe) = fitted("OWCK", 4, 100, 11);

        // Plain ClusterKriging artifact.
        let plain_path = dir.join("plain.ck");
        crate::surrogate::save_to_path(&model, &plain_path).unwrap();
        let out = split_artifact(&plain_path, 2, dir.join("plain_shards")).unwrap();
        assert_eq!(out.shard_paths.len(), 2);
        assert_eq!(out.assignment, vec![vec![0, 2], vec![1, 3]]);
        let manifest = ShardManifest::load_path(&out.manifest_path).unwrap();
        assert!(manifest.standardizer.is_none());
        let s0 = crate::surrogate::SurrogateSpec::load_path(&out.shard_paths[0]).unwrap();
        assert_eq!(s0.shard_predictor().unwrap().cluster_ids(), vec![0, 2]);

        // Standardized-wrapped artifact (what `ckrig fit --out` writes).
        let std = Standardizer {
            x_mean: vec![0.5, -0.5],
            x_std: vec![2.0, 2.0],
            y_mean: 1.0,
            y_std: 3.0,
        };
        let wrapped = crate::surrogate::Standardized::new(Box::new(model), std);
        let std_path = dir.join("standardized.ck");
        crate::surrogate::save_to_path(&wrapped, &std_path).unwrap();
        let out = split_artifact(&std_path, 2, dir.join("std_shards")).unwrap();
        let manifest = ShardManifest::load_path(&out.manifest_path).unwrap();
        assert!(manifest.standardizer.is_some());
        let s0 = crate::surrogate::SurrogateSpec::load_path(&out.shard_paths[0]).unwrap();
        let sp = s0.shard_predictor().expect("standardized shard forwards spredict");
        assert_eq!(sp.cluster_ids(), vec![0, 2]);
        // Raw-unit queries flow through the wrapper.
        assert!(sp.predict_clusters(&probe, None).is_ok());

        // Non-cluster artifacts are rejected with a clear message.
        let err = split_artifact(&std_path, 99, dir.join("x")).unwrap_err();
        assert!(err.to_string().contains("shards"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
