//! `ckrig` — the Cluster Kriging coordinator CLI.
//!
//! Subcommands:
//!   experiment  regenerate the paper's tables/figure data
//!   fit         fit one flavor on a dataset and score a holdout
//!   serve       start the TCP prediction server on a fitted model,
//!               a shard worker (--shard) or a scatter-gather
//!               coordinator (--manifest + --shards)
//!   shard       split a fitted Cluster Kriging artifact into per-worker
//!               shard artifacts + a coordinator manifest (protocol v5)
//!   stream      stream observations into a running server (protocol v3)
//!   optimize    run a budgeted ask/tell EGO loop on a benchmark function
//!   top         live dashboard over a running server's `metricsx` feed
//!   doctor      numerical-health report for an artifact or live server
//!   fitlog      render a `--telemetry` JSONL recording (phase timeline,
//!               hyperopt convergence, ingestion and optimizer traces)
//!   benchdiff   compare two bench JSON records and fail on regression
//!   info        show PJRT platform + discovered artifacts

use anyhow::{bail, Context, Result};
use cluster_kriging::coordinator::{
    BatcherConfig, Client, Health, ModelRegistry, ServeOptions, Server, ServerConfig,
    ServerMetrics, ShardPool, ShardPoolConfig,
};
use cluster_kriging::data::functions;
use cluster_kriging::data::synthetic::from_benchmark;
use cluster_kriging::data::{uci_like, Dataset, Standardizer};
use cluster_kriging::distributed::{self, ShardManifest, ShardedClusterKriging};
use cluster_kriging::eval::experiments::{run_all, ExperimentConfig};
use cluster_kriging::eval::report::{self, PaperTable};
use cluster_kriging::eval::HarnessConfig;
use cluster_kriging::kriging::{HyperOpt, NuggetMode, Surrogate};
use cluster_kriging::metrics;
use cluster_kriging::obs::{
    export, FitSink, FitTelemetry, HealthClass, HealthReport, Sampling, SloEngine, SloSpec, Tracer,
};
use cluster_kriging::online::wal::{self, Durability, DurabilityConfig, FsyncPolicy};
use cluster_kriging::online::{OnlineModel, OnlinePolicy, RefitConfig};
use cluster_kriging::optimize::{Acquisition, Bounds, Optimizer, OptimizerConfig};
use cluster_kriging::stream::{fit_stream, CsvRowSource, StreamFitConfig};
use cluster_kriging::surrogate::{self, FitOptions, Standardized, SurrogateSpec};
use cluster_kriging::util::cli::Args;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Flipped by the SIGTERM/SIGINT handler; the serve loops poll it and
/// drain instead of dying mid-request.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        // Only async-signal-safe work here: one atomic store.
        SHUTDOWN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_signal as usize);
        signal(SIGINT, on_signal as usize);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

fn main() {
    // Structured JSONL logging on stderr, filtered by CKRIG_LOG
    // (off|error|warn|info|debug; default info), optional file sink via
    // CKRIG_LOG_FILE. Replaces the old ad-hoc env_logger substitute.
    cluster_kriging::obs::log::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("experiment") => cmd_experiment(&args),
        Some("fit") => cmd_fit(&args),
        Some("serve") => cmd_serve(&args),
        Some("shard") => cmd_shard(&args),
        Some("stream") => cmd_stream(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("top") => cmd_top(&args),
        Some("doctor") => cmd_doctor(&args),
        Some("fitlog") => cmd_fitlog(&args),
        Some("benchdiff") => cmd_benchdiff(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "ckrig — Cluster Kriging (van Stein et al., 2017)\n\
         \n\
         USAGE: ckrig <experiment|fit|serve|top|doctor|info> [options]\n\
         \n\
         experiment --table 1|2|3 | --figure 2 [--paper-scale] [--folds N]\n\
         \u{20}          [--datasets a,b] [--algos SoD,MTCK] [--out results/]\n\
         fit        --dataset <name> --algo SPEC [--seed S] [--n N] [--out model.ck]\n\
         \u{20}          [--degenerate]  (duplicate every training row and pin the\n\
         \u{20}           nugget near zero — a conditioning stress fixture for\n\
         \u{20}           `ckrig doctor`)\n\
         \u{20}          [--telemetry out.jsonl] [--progress]  (fit-path telemetry:\n\
         \u{20}           per-phase timings, per-eval hyperopt traces; render with\n\
         \u{20}           `ckrig fitlog out.jsonl`)\n\
         \u{20}          (or legacy --flavor OWCK|OWFCK|GMMCK|MTCK --k K)\n\
         \u{20}          (streaming: --stream data.csv --memory-budget MB [--k K]\n\
         \u{20}           [--chunk-rows N] [--no-header] — bounded-memory two-pass\n\
         \u{20}           multiscale fit; the CSV is never fully resident)\n\
         serve      --artifact model.ck [--name SLOT] [--addr host:port]\n\
         \u{20}          (or fit-then-serve: --dataset <name> --algo SPEC)\n\
         \u{20}          [--staleness N] [--drift-z Z] [--drift-window W]\n\
         \u{20}          [--window N] (sliding-window eviction: keep serving\n\
         \u{20}           O(window²) forever)  [--drift-evict F] (on drift, shed\n\
         \u{20}           the oldest F·window points instead of refitting)\n\
         \u{20}          [--wal DIR [--fsync always|never|every-N|interval-MS]\n\
         \u{20}           [--checkpoint-every N]]  (durable observe + crash recovery;\n\
         \u{20}           SIGTERM/SIGINT drain, checkpoint, and exit cleanly)\n\
         \u{20}          [--trace-sample N] [--trace-capacity M]  (request tracing:\n\
         \u{20}           0=forced `trace=` only (default), 1=every request, N=1-in-N;\n\
         \u{20}           dump a tree with the `trace <id>` protocol op)\n\
         \u{20}          [--slo p99=5ms,err=0.1%,miscal=off]  (SLO alerting:\n\
         \u{20}           rolling-window latency/error/calibration statuses in\n\
         \u{20}           `health`, `stats`, `metricsx` and `ckrig top`; state\n\
         \u{20}           transitions log one structured warn each)\n\
         \u{20}          (shard worker: --shard dir/shard-0.ck)\n\
         \u{20}          (coordinator: --manifest dir/manifest.ck\n\
         \u{20}           --shards host0:port,host1:port,… [--shard-timeout MS])\n\
         shard      --artifact model.ck --shards N [--out DIR]\n\
         stream     --addr host:port --dataset <name> [--n N] [--batch B]\n\
         \u{20}          [--model SLOT] [--seed S] [--drift D]\n\
         optimize   --algo SPEC --fn <benchmark> --budget N [--init N] [--q B]\n\
         \u{20}          [--acq ei|poi|lcb[:v]] [--pool P] [--dim D] [--seed S]\n\
         \u{20}          [--telemetry out.jsonl] [--progress]  (per-iteration\n\
         \u{20}           incumbent/acquisition traces + refit phases)\n\
         top        [--addr host:port] [--interval MS] [--once]  (live dashboard:\n\
         \u{20}          counters, latency percentiles, per-model calibration,\n\
         \u{20}          conditioning and SLO status)\n\
         doctor     --artifact model.ck | --addr host:port  (numerical-health\n\
         \u{20}          report: per-cluster condition estimates, escalated jitter,\n\
         \u{20}          cluster balance, degeneracy counters, WAL lag, SLO table;\n\
         \u{20}          exits non-zero on critical conditioning or SLO breach)\n\
         fitlog     <telemetry.jsonl>  (phase timeline, hyperopt convergence,\n\
         \u{20}          ingestion/optimizer traces from a --telemetry recording)\n\
         benchdiff  <old.json> <new.json> [--gate PCT]  (compare bench records;\n\
         \u{20}          non-zero exit when any gated metric regressed past PCT,\n\
         \u{20}          default 10)\n\
         info       [--artifacts DIR]\n\
         \n\
         SPEC names any algorithm: mtck:8 owck:4 sod:512 fitc:64 bcm:8\n\
         \u{20}    bcm-sh:8 multiscale:8 kriging — `fit --out` writes a binary artifact that\n\
         \u{20}    `serve --artifact` boots in milliseconds (no refit); the live\n\
         \u{20}    server hot-swaps models via `load <path> [name]` + `swap <name>`,\n\
         \u{20}    absorbs `observe`/`observeb` traffic in place (O(n_c²) cluster-\n\
         \u{20}    local updates), and background-refits when the staleness budget\n\
         \u{20}    or the drift monitor says the stream outgrew the fit.\n\
         \n\
         datasets: concrete ccpp sarcos ackley schaffer schwefel rast h1\n\
         \u{20}         rosenbrock himmelblau diffpow"
    );
}

/// Resolve a dataset name to generated data (paper regimes).
fn load_dataset(name: &str, seed: u64, n_override: Option<usize>) -> Result<Dataset> {
    let ds = match name {
        "concrete" => uci_like::concrete_sized(n_override.unwrap_or(1030), seed),
        "ccpp" => uci_like::ccpp_sized(n_override.unwrap_or(9568), seed),
        "sarcos" => uci_like::sarcos(seed, 0.09).0,
        other => {
            let b = functions::by_name(other)
                .with_context(|| format!("unknown dataset {other:?}"))?;
            from_benchmark(b, n_override.unwrap_or(2000), 20, 0.0, seed)
        }
    };
    Ok(ds)
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let table: Option<usize> = args.get_parsed_or("table", 0).ok().filter(|&t| t > 0);
    let figure: Option<usize> = args.get_parsed_or("figure", 0).ok().filter(|&f| f > 0);
    if table.is_none() && figure.is_none() {
        bail!("pass --table 1|2|3 or --figure 2 (or both)");
    }
    let cfg = ExperimentConfig {
        paper_scale: args.has_flag("paper-scale"),
        folds: args.get_parsed_or("folds", 3)?,
        harness: if args.has_flag("full-hyperopt") {
            HarnessConfig::default()
        } else {
            HarnessConfig::fast()
        },
        seed: args.get_parsed_or("seed", 0xE8u64)?,
        only_datasets: args.get_list::<String>("datasets")?.unwrap_or_default(),
        only_algos: args.get_list::<String>("algos")?.unwrap_or_default(),
    };
    log::info!(
        "running experiment grid (paper_scale={}, folds={})",
        cfg.paper_scale,
        cfg.folds
    );
    let grids = run_all(&cfg)?;

    let out_dir = args.get_or("out", "results");
    std::fs::create_dir_all(out_dir).ok();

    if let Some(t) = table {
        // Tables II/III are free projections of the same grid — always
        // persist all three; print the requested one.
        let requested = match t {
            1 => PaperTable::R2,
            2 => PaperTable::Msll,
            3 => PaperTable::Smse,
            _ => bail!("--table must be 1, 2 or 3"),
        };
        for (idx, pt) in
            [(1, PaperTable::R2), (2, PaperTable::Msll), (3, PaperTable::Smse)]
        {
            let md = report::render_table(&grids, pt);
            if pt == requested {
                println!("{md}");
            }
            let path = format!("{out_dir}/table{idx}.md");
            std::fs::write(&path, &md)?;
            log::info!("wrote {path}");
        }
    }
    if let Some(f) = figure {
        if f != 2 {
            bail!("--figure must be 2");
        }
        let csv = report::fig2_csv(&grids);
        let path = format!("{out_dir}/fig2.csv");
        std::fs::write(&path, &csv)?;
        let rows: usize = grids.iter().flatten().map(|c| c.sweep.len()).sum();
        log::info!("wrote {path} ({rows} rows)");
    }
    Ok(())
}

/// Resolve the algorithm spec from `--algo SPEC` (preferred) or the
/// legacy `--flavor F --k K` pair.
fn resolve_spec(args: &Args, default_spec: &str) -> Result<SurrogateSpec> {
    if let Some(spec) = args.get("algo") {
        return SurrogateSpec::parse(spec);
    }
    if let Some(flavor) = args.get("flavor") {
        let k: usize = args.get_parsed_or("k", 4)?;
        return SurrogateSpec::parse(&format!("{flavor}:{k}"));
    }
    SurrogateSpec::parse(default_spec)
}

/// Build the fit-path telemetry recorder from `--telemetry PATH` and/or
/// `--progress`: the recorder (kept for the final dump), a top-level
/// [`FitSink`] to thread through the pipelines, and the dump path.
fn telemetry_from_args(args: &Args) -> (Option<Arc<FitTelemetry>>, Option<FitSink>) {
    if args.get("telemetry").is_none() && !args.has_flag("progress") {
        return (None, None);
    }
    let rec = Arc::new(FitTelemetry::with_progress(args.has_flag("progress")));
    let sink = FitSink::new(Arc::clone(&rec));
    (Some(rec), Some(sink))
}

/// Stamp the recording's footer and write the JSONL file, if recording.
fn telemetry_finish(args: &Args, rec: &Option<Arc<FitTelemetry>>, label: &str) -> Result<()> {
    let Some(rec) = rec else { return Ok(()) };
    rec.finish(label);
    if let Some(path) = args.get("telemetry") {
        let n = rec.dump_to_path(path)?;
        println!("telemetry   : {path} ({n} events) — render with `ckrig fitlog {path}`");
    }
    Ok(())
}

/// Fit a spec on a dataset's 80% training fold through the one shared
/// `SurrogateSpec::fit` path, wrapped with the fold's standardizer so the
/// model (and its artifact) serves raw-unit queries. Returns the holdout
/// fold alongside. `telemetry` (already nested under the caller's
/// top-level phase) records per-eval hyperopt traces when set.
fn fit_spec(
    ds: &Dataset,
    spec: &SurrogateSpec,
    seed: u64,
    telemetry: Option<FitSink>,
    nugget: Option<f64>,
) -> Result<(Standardized, Dataset)> {
    let (train, test) = ds.split(0.8, seed);
    // Standardize on the training fold (as the evaluation harness does) —
    // the θ search bounds assume unit-scale inputs.
    let std = Standardizer::fit(&train);
    let tr = std.transform(&train);
    let mut hyperopt = HyperOpt {
        restarts: 1,
        max_evals: 20,
        isotropic: tr.d() > 8,
        telemetry,
        ..HyperOpt::default()
    };
    if let Some(v) = nugget {
        hyperopt.nugget = NuggetMode::Fixed(v);
    }
    let opts = FitOptions { hyperopt, seed };
    let model = spec.fit(&tr, &opts)?;
    Ok((Standardized::new(model, std), test))
}

fn cmd_fit(args: &Args) -> Result<()> {
    if let Some(path) = args.get("stream") {
        return cmd_fit_stream(args, path);
    }
    let dataset: String = args.require("dataset")?;
    let seed: u64 = args.get_parsed_or("seed", 1)?;
    let n: Option<usize> = args.get_parsed_or("n", 0).ok().filter(|&n| n > 0);
    let spec = resolve_spec(args, "mtck:4")?;

    let (rec, sink) = telemetry_from_args(args);
    let phase = sink.as_ref().map(|s| s.phase("load-data"));
    let mut ds = load_dataset(&dataset, seed, n)?;
    drop(phase);
    // --degenerate: duplicate every training row and pin the nugget near
    // zero, so the correlation matrix is numerically singular and the
    // factorization must escalate jitter — a stress fixture for
    // `ckrig doctor` and the CI conditioning smoke.
    let degenerate = args.has_flag("degenerate");
    let nugget = if degenerate {
        let idx: Vec<usize> = (0..ds.n()).flat_map(|i| [i, i]).collect();
        ds = ds.subset(&idx);
        ds.name.push_str("+dup");
        Some(1e-12)
    } else {
        None
    };
    log::info!("dataset {} ({}×{}), algo {spec}", ds.name, ds.n(), ds.d());
    let t0 = std::time::Instant::now();
    let phase = sink.as_ref().map(|s| s.phase("fit"));
    let (model, test) = fit_spec(&ds, &spec, seed, sink.as_ref().map(|s| s.nested()), nugget)?;
    drop(phase);
    let fit_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let phase = sink.as_ref().map(|s| s.phase("predict"));
    let pred = model.predict(&test.x)?;
    drop(phase);
    let pred_s = t1.elapsed().as_secs_f64();

    println!("algo        : {} ({spec})", model.name());
    println!("fit_seconds : {fit_s:.3}");
    println!("pred_seconds: {pred_s:.3}");
    println!("R2          : {:.4}", metrics::r2(&test.y, &pred.mean));
    println!("SMSE        : {:.4}", metrics::smse(&test.y, &pred.mean));

    if let Some(out) = args.get("out") {
        let t2 = std::time::Instant::now();
        let phase = sink.as_ref().map(|s| s.phase("save"));
        let bytes = surrogate::save_to_path(&model, out)?;
        drop(phase);
        println!(
            "artifact    : {out} ({bytes} bytes, written in {:.3}s)",
            t2.elapsed().as_secs_f64()
        );
        println!("serve it    : ckrig serve --artifact {out}");
    }
    telemetry_finish(args, &rec, &format!("fit {dataset} {spec}"))?;
    Ok(())
}

/// Bounded-memory fit from a CSV that is never fully resident: two
/// chunked passes over the file build a multiscale (coarse trend +
/// per-cluster residual) ensemble while a hard ledger keeps peak
/// resident bytes under `--memory-budget` MB.
fn cmd_fit_stream(args: &Args, path: &str) -> Result<()> {
    let budget_mb: usize = args.get_parsed_or("memory-budget", 256)?;
    anyhow::ensure!(budget_mb > 0, "--memory-budget is in MB and must be positive");
    let default_k: usize = args.get_parsed_or("k", 8)?;
    let k = match resolve_spec(args, &format!("multiscale:{default_k}"))? {
        SurrogateSpec::Multiscale { k } => k,
        other => bail!("fit --stream builds the multiscale flavor; got --algo {other}"),
    };
    let chunk_rows: usize = args.get_parsed_or("chunk-rows", 4096)?;
    anyhow::ensure!(chunk_rows > 0, "--chunk-rows must be positive");
    let has_header = !args.has_flag("no-header");

    let (rec, sink) = telemetry_from_args(args);
    let cfg = StreamFitConfig {
        chunk_rows,
        seed: args.get_parsed_or("seed", 1)?,
        telemetry: sink.clone(),
        ..StreamFitConfig::new(k, budget_mb << 20)
    };
    let mut src = CsvRowSource::open(path, cfg.chunk_rows, has_header)?;
    log::info!("streaming {path} (budget {budget_mb} MB, k={k}, chunks of {chunk_rows} rows)");
    let t0 = std::time::Instant::now();
    let phase = sink.as_ref().map(|s| s.phase("stream-fit"));
    let (model, rep) = fit_stream(&mut src, &cfg)?;
    drop(phase);
    let fit_s = t0.elapsed().as_secs_f64();

    let peak = rep.peak_bytes as f64 / (1u64 << 20) as f64;
    let total = rep.budget_bytes as f64 / (1u64 << 20) as f64;
    println!("algo        : {} (multiscale:{k})", model.name());
    println!("rows        : {} in {} chunks ({} dims)", rep.rows, rep.chunks, rep.d);
    println!("fit_seconds : {fit_s:.3}");
    println!("cap/model   : {} points", rep.cap_per_model);
    println!("coarse      : {} points", rep.coarse_points);
    println!("clusters    : {:?} points", rep.cluster_points);
    println!("dropped     : {} rows", rep.dropped_rows);
    println!("peak memory : {peak:.1} MB of {total:.1} MB budget");

    if let Some(out) = args.get("out") {
        let phase = sink.as_ref().map(|s| s.phase("save"));
        let bytes = surrogate::save_to_path(&model, out)?;
        drop(phase);
        println!("artifact    : {out} ({bytes} bytes)");
        println!("serve it    : ckrig serve --artifact {out}");
    }
    telemetry_finish(args, &rec, &format!("fit-stream {path} multiscale:{k}"))?;
    Ok(())
}

/// Build the serve-side span recorder from `--trace-sample N` (0 = off:
/// only client-forced `trace=` requests record; 1 = every request;
/// N = one request in N) and `--trace-capacity M` (ring size).
fn tracer_from_args(args: &Args) -> Result<Arc<Tracer>> {
    let sample: u64 = args.get_parsed_or("trace-sample", 0u64)?;
    let capacity: usize =
        args.get_parsed_or("trace-capacity", cluster_kriging::obs::trace::DEFAULT_CAPACITY)?;
    anyhow::ensure!(capacity > 0, "--trace-capacity must be positive");
    let sampling = match sample {
        0 => Sampling::Off,
        1 => Sampling::Always,
        n => Sampling::Sampled(n),
    };
    Ok(Arc::new(Tracer::new(capacity, sampling)))
}

/// Build the SLO alerting engine from `--slo SPEC` (e.g.
/// `p99=5ms,err=0.1%,miscal=off`); `None` when the flag is absent, which
/// disables SLO evaluation entirely.
fn slo_from_args(args: &Args) -> Result<Option<Arc<SloEngine>>> {
    match args.get("slo") {
        Some(spec) => {
            let spec =
                SloSpec::parse(spec).map_err(|e| anyhow::anyhow!("parsing --slo: {e}"))?;
            log::info!("SLO alerting on: {spec}");
            Ok(Some(Arc::new(SloEngine::new(spec))))
        }
        None => Ok(None),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7471").to_string();
    let name = args.get_or("name", "default").to_string();
    // Chaos testing: arm named fault-injection points for this process.
    // Errors loudly on a binary built without the feature, so a chaos
    // suite can never silently run against an uninstrumented server.
    if let Some(spec) = args.get("faults") {
        cluster_kriging::util::faults::arm(spec)?;
    }
    if let Some(manifest_path) = args.get("manifest") {
        return serve_coordinator(args, &addr, &name, manifest_path);
    }
    let policy = OnlinePolicy {
        staleness_budget: args.get_parsed_or("staleness", 512)?,
        drift_window: args.get_parsed_or("drift-window", 64)?,
        drift_zscore: args.get_parsed_or("drift-z", 3.0)?,
        window: args.get_parsed_or("window", 0)?,
        drift_evict: args.get_parsed_or("drift-evict", 0.0)?,
        ..OnlinePolicy::default()
    };

    // `refit` carries the spec when we fitted it ourselves (fit-then-
    // serve); artifact boots don't know their spec, so they observe
    // incrementally without policy-triggered refits.
    // `--shard` is the worker role of a sharded deployment: same boot
    // path as `--artifact` (shard artifacts are ordinary servable
    // models), announced with its slice of the topology.
    let artifact_arg = args.get("artifact").or_else(|| args.get("shard"));

    // Durability (--wal DIR): recover the checkpoint + WAL tail before
    // anything serves, then log every acknowledged observation ahead of
    // applying it. A recovered checkpoint overrides --artifact — it is a
    // later durable state of the same model.
    let fsync = FsyncPolicy::parse(args.get_or("fsync", "always"))?;
    let checkpoint_every: u64 = args.get_parsed_or("checkpoint-every", 1024u64)?;
    let wal_dir = args.get("wal").map(PathBuf::from);
    let mut recovery = match &wal_dir {
        Some(dir) => Some(wal::recover(dir, fsync)?),
        None => None,
    };
    let recovered = recovery.as_mut().and_then(|r| r.checkpoint.take());

    let (model, refit): (Box<dyn Surrogate>, Option<RefitConfig>) =
        if let Some((seq, model)) = recovered {
            log::info!(
                "recovered checkpoint at seq {seq}: {} ({} dims) from {}",
                model.name(),
                model.dim(),
                wal_dir.as_ref().expect("checkpoint implies --wal").display()
            );
            (model, None)
        } else if let Some(artifact) = artifact_arg {
            // Millisecond cold boot: load the fitted model, no refit.
            let t0 = std::time::Instant::now();
            let model = SurrogateSpec::load_path(artifact)?;
            log::info!(
                "loaded {} ({} dims) from {artifact} in {:.1} ms",
                model.name(),
                model.dim(),
                t0.elapsed().as_secs_f64() * 1e3
            );
            if args.get("shard").is_some() {
                let sp = model.shard_predictor().context(
                    "serve --shard needs a shard (or Cluster Kriging) artifact; \
                     this model has no per-cluster decomposition",
                )?;
                let (i, s) = sp.shard_index().unwrap_or((0, 1));
                log::info!(
                    "shard worker {i}/{s}: serving clusters {:?} of {} (spredict/shardinfo ready)",
                    sp.cluster_ids(),
                    sp.k_total()
                );
            }
            (model, None)
        } else {
            let dataset: String = args.require("dataset").context(
                "serve needs --artifact model.ck (preferred) or --dataset to fit-then-serve",
            )?;
            let seed: u64 = args.get_parsed_or("seed", 1)?;
            let n: Option<usize> = args.get_parsed_or("n", 0).ok().filter(|&v| v > 0);
            let spec = resolve_spec(args, "mtck:4")?;
            let ds = load_dataset(&dataset, seed, n)?;
            log::info!("fitting {spec} on {} ({}×{})", ds.name, ds.n(), ds.d());
            let (model, _) = fit_spec(&ds, &spec, seed, None, None)?;
            let refit = RefitConfig { spec, opts: FitOptions::fast() };
            (Box::new(model), Some(refit))
        };

    let mut model = model;
    let durability = match recovery {
        Some(rec) => {
            if !rec.replay.is_empty() {
                let n = wal::replay_into(model.as_mut(), &rec.replay, &name)?;
                log::info!("replayed {n} WAL observations into slot {name:?}");
            }
            let dir = wal_dir.clone().expect("recovery implies --wal");
            Some(Durability::new(rec.wal, &DurabilityConfig { dir, fsync, checkpoint_every }))
        }
        None => None,
    };

    let dim = model.dim();
    // Online-capable models serve behind the OnlineModel adapter so the
    // protocol's observe/observeb ops work; fit-once models serve as-is.
    let (model, online): (Arc<dyn Surrogate>, Option<Arc<OnlineModel>>) =
        match OnlineModel::try_new(model, policy) {
            Ok(adapter) => {
                let adapter = match refit {
                    Some(cfg) => adapter.with_refit(cfg),
                    None => adapter,
                };
                let adapter = Arc::new(adapter);
                (Arc::clone(&adapter) as Arc<dyn Surrogate>, Some(adapter))
            }
            Err(inner) => {
                log::warn!(
                    "{} is fit-once; observe/observeb will be rejected",
                    inner.name()
                );
                (Arc::from(inner), None)
            }
        };
    let registry = Arc::new(ModelRegistry::new(name.clone(), model));
    if let Some(adapter) = &online {
        adapter.bind(&registry, &name);
    }
    let health = Health::new();
    let mut server = Server::start_with_options(
        Arc::clone(&registry),
        ServerConfig { addr, batcher: BatcherConfig::default() },
        ServeOptions {
            metrics: Arc::new(ServerMetrics::new()),
            wal: durability.clone(),
            health: Arc::clone(&health),
            tracer: tracer_from_args(args)?,
            pool: None,
            slo: slo_from_args(args)?,
        },
    )?;
    let ckpt_stop = Arc::new(AtomicBool::new(false));
    let checkpointer = durability
        .as_ref()
        .map(|d| wal::spawn_checkpointer(d, &registry, &name, Arc::clone(&ckpt_stop)));
    if let Some(d) = &durability {
        // Mark the WAL attached before the address is announced, so the
        // very first `health` reply already carries the wal fields.
        health.observe_wal(d);
    }
    println!(
        "serving on {} — protocol: `predict [model] x1,...,x{dim}` | \
         `predictb [model] <n> <p1;p2;...>` | `observe [model] x1,...,x{dim},y` | \
         `observeb [model] <n> <o1;o2;...>` | `suggest [model] <q> [bounds]` | \
         `tell [model] x1,...,x{dim},y` | `models` | `load <path> [name]` | \
         `swap <name>` | `stats` | `health` | `ping`",
        server.local_addr
    );
    install_signal_handlers();
    let mut ticks = 0u64;
    while !SHUTDOWN.load(Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(250));
        if let Some(d) = &durability {
            health.observe_wal(d);
        }
        ticks += 1;
        if ticks % 20 != 0 {
            continue;
        }
        // Resolve the slot each tick: background refits hot-swap fresh
        // adapter generations in, and their counters are per-generation.
        let live = server
            .registry()
            .get(Some(name.as_str()))
            .and_then(|m| m.observer().map(|o| o.online_stats()));
        match live {
            Some(s) => log::info!(
                "{} | online: observed={} since_refit={} refits={} refit_in_flight={} \
                 last_refit_us={} drift={:.2} points={} evicted={} bytes={}",
                server.metrics.summary(),
                s.observed,
                s.since_refit,
                s.refits,
                s.refit_in_flight,
                s.last_refit_duration_us,
                s.drift,
                s.train_points,
                s.evicted,
                s.resident_bytes
            ),
            None => log::info!("{}", server.metrics.summary()),
        }
    }
    // Graceful drain: stop accepting, let in-flight requests and the
    // flush queue finish, then make the absorbed state durable so the
    // next boot replays nothing.
    log::info!("signal received; draining");
    server.shutdown();
    ckpt_stop.store(true, Ordering::SeqCst);
    if let Some(handle) = checkpointer {
        let _ = handle.join();
    }
    if let Some(d) = &durability {
        if let Some(m) = registry.get(Some(name.as_str())) {
            let seq = d.checkpoint(m.as_ref())?;
            log::info!("final checkpoint at seq {seq}");
        }
        d.flush()?;
    }
    log::info!("drained; exiting");
    Ok(())
}

/// Boot the scatter-gather coordinator role (protocol v5): load a shard
/// manifest, connect the persistent pool to the worker fleet, and serve
/// the ordinary `predict`/`predictb`/`observe` protocol on top of it —
/// clients cannot tell a coordinator from a monolithic server except by
/// the `stats` line's shard fields.
fn serve_coordinator(args: &Args, addr: &str, name: &str, manifest_path: &str) -> Result<()> {
    let shards: Vec<String> = args.get_list("shards")?.context(
        "serve --manifest needs --shards addr0,addr1,… (one worker address per shard, \
         in shard-index order)",
    )?;
    let manifest = ShardManifest::load_path(manifest_path)?;
    let pool_cfg = ShardPoolConfig {
        request_timeout: std::time::Duration::from_millis(
            args.get_parsed_or("shard-timeout", 5_000u64)?,
        ),
        ..ShardPoolConfig::default()
    };
    let pool = ShardPool::connect(&shards, &manifest, pool_cfg)?;
    log::info!(
        "shard pool up: {}/{} workers healthy",
        pool.alive_count(),
        pool.shard_count()
    );
    let model = ShardedClusterKriging::new(manifest, Arc::clone(&pool))?;
    let dim = model.dim();
    log::info!(
        "coordinating {} — {} clusters across {} shards, combiner {}",
        model.name(),
        model.manifest().k_total,
        model.manifest().shard_count(),
        model.manifest().combiner.name()
    );
    let registry = Arc::new(ModelRegistry::new(name.to_string(), Arc::new(model)));
    let metrics = Arc::new(ServerMetrics::new());
    pool.attach_metrics(Arc::clone(&metrics));
    let health = Health::new();
    pool.attach_health(Arc::clone(&health));
    // No --wal on the coordinator: observations are durable on the shard
    // workers that own them, not on the router in front of them.
    let mut server = Server::start_with_options(
        registry,
        ServerConfig { addr: addr.to_string(), batcher: BatcherConfig::default() },
        ServeOptions {
            metrics,
            wal: None,
            health,
            tracer: tracer_from_args(args)?,
            pool: Some(Arc::clone(&pool)),
            slo: slo_from_args(args)?,
        },
    )?;
    println!(
        "serving on {} — scatter-gather coordinator: `predict [model] x1,...,x{dim}` | \
         `predictb [model] <n> <p1;p2;...>` | `observe [model] x1,...,x{dim},y` | \
         `observeb [model] <n> <o1;o2;...>` | `stats` | `health` | `ping` \
         (observations route to the owning shard)",
        server.local_addr
    );
    install_signal_handlers();
    let mut ticks = 0u64;
    while !SHUTDOWN.load(Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(250));
        ticks += 1;
        if ticks % 20 != 0 {
            continue;
        }
        log::info!(
            "{} | shards alive {}/{} degraded_merges={} retries={}",
            server.metrics.summary(),
            pool.alive_count(),
            pool.shard_count(),
            pool.degraded_merges(),
            pool.retried_requests()
        );
    }
    log::info!("signal received; draining");
    server.shutdown();
    log::info!("drained; exiting");
    Ok(())
}

/// Split a fitted Cluster Kriging artifact into per-worker shard
/// artifacts plus the coordinator manifest — the offline half of
/// distributed serving.
fn cmd_shard(args: &Args) -> Result<()> {
    let artifact: String = args.require("artifact")?;
    let shards: usize = args.require("shards")?;
    let out = args.get_or("out", "shards");
    let t0 = std::time::Instant::now();
    let result = distributed::split_artifact(&artifact, shards, out)?;
    for (path, clusters) in result.shard_paths.iter().zip(&result.assignment) {
        println!("wrote {} (clusters {clusters:?})", path.display());
    }
    println!(
        "wrote {} (split {} shards in {:.3}s)",
        result.manifest_path.display(),
        result.shard_paths.len(),
        t0.elapsed().as_secs_f64()
    );
    println!();
    println!("start one worker per shard, then the coordinator:");
    for (i, path) in result.shard_paths.iter().enumerate() {
        println!("  ckrig serve --shard {} --addr host{i}:port", path.display());
    }
    println!(
        "  ckrig serve --manifest {} --shards addr0,addr1,… --addr host:port",
        result.manifest_path.display()
    );
    Ok(())
}

/// Stream a dataset's rows into a running server as observations — the
/// client side of protocol v3. `--drift D` adds a constant offset to
/// every streamed target, handy for demonstrating the server's drift
/// monitor and background refit.
fn cmd_stream(args: &Args) -> Result<()> {
    let addr: String = args.require("addr")?;
    let dataset: String = args.require("dataset")?;
    let seed: u64 = args.get_parsed_or("seed", 7)?;
    let n: usize = args.get_parsed_or("n", 512)?;
    let batch: usize = args.get_parsed_or("batch", 16)?.max(1);
    let drift: f64 = args.get_parsed_or("drift", 0.0)?;
    let model = args.get("model").map(str::to_string);

    let ds = load_dataset(&dataset, seed, Some(n))?;
    let mut client = Client::connect(&addr)
        .with_context(|| format!("connecting to server at {addr}"))?;
    log::info!(
        "streaming {} observations from {} ({} dims) to {addr} in batches of {batch}",
        ds.n(),
        ds.name,
        ds.d()
    );
    let t0 = std::time::Instant::now();
    let mut sent = 0;
    while sent < ds.n() {
        let hi = (sent + batch).min(ds.n());
        let points: Vec<&[f64]> = (sent..hi).map(|i| ds.x.row(i)).collect();
        let ys: Vec<f64> = (sent..hi).map(|i| ds.y[i] + drift).collect();
        client.observe_batch(model.as_deref(), &points, &ys)?;
        sent = hi;
        if sent % (batch * 8) == 0 || sent == ds.n() {
            log::info!("{sent}/{} | server: {}", ds.n(), client.stats()?);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "streamed {sent} observations in {secs:.2}s ({:.0} obs/s)",
        sent as f64 / secs
    );
    println!("final server stats: {}", client.stats()?);
    Ok(())
}

/// Run a budgeted ask/tell EGO loop (minimization) against one of the
/// named benchmark functions — the `optimize/` subsystem driven end to
/// end from the command line, with a seeded random-search baseline at the
/// same evaluation budget for reference.
fn cmd_optimize(args: &Args) -> Result<()> {
    let fn_name: String = args.require("fn")?;
    let budget: usize = args.require("budget")?;
    let q: usize = args.get_parsed_or("q", 1)?.max(1);
    let seed: u64 = args.get_parsed_or("seed", 17)?;
    let spec = resolve_spec(args, "mtck:4")?;
    let acq = Acquisition::parse(args.get_or("acq", "ei"))?;

    let bench = functions::by_name(&fn_name)
        .with_context(|| format!("unknown benchmark function {fn_name:?}"))?;
    let d = bench.fixed_dim.unwrap_or(args.get_parsed_or("dim", 2)?).max(1);
    let (lo, hi) = bench.domain;
    let bounds = Bounds::cube(d, lo, hi)?;
    let init: usize = match args.get("init") {
        Some(_) => args.get_parsed_or("init", 0)?,
        // Default: a quarter of the budget, floored at ~2 points per
        // dimension and capped so most of the budget is model-guided.
        // The floor wins over the cap in high dimension (d ≥ 10, where
        // 2d+2 > 20) — clamp(lo, hi) requires lo ≤ hi.
        None => {
            let floor = 2 * d + 2;
            (budget / 4).clamp(floor, floor.max(20))
        }
    };
    anyhow::ensure!(budget > init, "--budget {budget} must exceed the initial design {init}");

    let (rec, sink) = telemetry_from_args(args);
    let cfg = OptimizerConfig {
        acquisition: acq,
        pool: args.get_parsed_or("pool", 512)?,
        init,
        seed,
        telemetry: sink.clone(),
        ..OptimizerConfig::new(spec.clone())
    };
    log::info!(
        "minimizing {fn_name} (d={d}, domain [{lo}, {hi}]) with {spec}: \
         budget {budget}, init {init}, q={q}, acquisition {acq}"
    );
    let mut opt = Optimizer::new(bounds, cfg)?;
    let t0 = std::time::Instant::now();
    let phase = sink.as_ref().map(|s| s.phase("optimize-loop"));
    let mut evals = 0;
    while evals < budget {
        let ask_q = q.min(budget - evals);
        let xs = opt.ask(ask_q)?;
        for i in 0..xs.rows() {
            let x = xs.row(i).to_vec();
            let y = (bench.eval)(&x);
            opt.tell(&x, y)?;
            evals += 1;
        }
        if evals % 10 < ask_q || evals == budget {
            let (_, best) = opt.best().expect("told at least one evaluation");
            log::info!("eval {evals}/{budget}: best {best:.6}");
        }
    }
    drop(phase);
    let secs = t0.elapsed().as_secs_f64();
    let (best_x, best_y) = opt.best().expect("budget > 0");
    let stats = opt.stats();

    // Random-search baseline at the same budget, same seed stream.
    let mut rng = cluster_kriging::util::rng::Rng::new(seed);
    let mut rand_best = f64::INFINITY;
    for _ in 0..budget {
        let p: Vec<f64> = (0..d).map(|_| rng.uniform_in(lo, hi)).collect();
        rand_best = rand_best.min((bench.eval)(&p));
    }

    println!("function      : {fn_name} (d={d})");
    println!("algo          : {spec} | acquisition {acq}");
    println!("evaluations   : {budget} ({init} initial design, q={q})");
    println!("best found    : {best_y:.6} at {best_x:?}");
    println!("random search : {rand_best:.6} (same budget)");
    println!(
        "driver        : {} fits, {} incremental tells, {:.2}s wall",
        stats.fits, stats.incremental, secs
    );
    telemetry_finish(args, &rec, &format!("optimize {fn_name} {spec}"))?;
    Ok(())
}

/// Render a `--telemetry` JSONL recording: phase timeline, hyperopt
/// convergence table, ingestion and optimizer traces.
fn cmd_fitlog(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.get("input"))
        .context("usage: ckrig fitlog <telemetry.jsonl>")?;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading telemetry log {path}"))?;
    let events = cluster_kriging::obs::fitlog::parse_jsonl(&text)?;
    print!("{}", cluster_kriging::obs::fitlog::render(&events));
    Ok(())
}

/// Compare two bench JSON records leaf by leaf and exit non-zero when
/// any gated metric regressed past `--gate PCT` (default 10%) — the CI
/// bench-regression gate.
fn cmd_benchdiff(args: &Args) -> Result<()> {
    let (old_path, new_path) = match args.positional.as_slice() {
        [o, n] => (o.as_str(), n.as_str()),
        _ => bail!("usage: ckrig benchdiff <old.json> <new.json> [--gate PCT]"),
    };
    let gate: f64 = args.get_parsed_or("gate", 10.0)?;
    anyhow::ensure!(gate.is_finite() && gate >= 0.0, "--gate must be a non-negative percent");
    let old_text = std::fs::read_to_string(old_path)
        .with_context(|| format!("reading old bench record {old_path}"))?;
    let new_text = std::fs::read_to_string(new_path)
        .with_context(|| format!("reading new bench record {new_path}"))?;
    let report = cluster_kriging::obs::benchdiff::compare(&old_text, &new_text, gate)?;
    print!("{}", cluster_kriging::obs::benchdiff::render(&report, gate));
    if !report.regressions.is_empty() {
        bail!(
            "{} of {} gated metrics regressed past the {gate}% gate",
            report.regressions.len(),
            report.compared
        );
    }
    Ok(())
}

/// Live terminal dashboard over a running server: poll the `metricsx`
/// exposition (plus the one-line `stats` reply for the raw view), parse
/// it with the same parser the tests use, and render counters, latency
/// percentiles and per-model calibration with a `[MISCALIBRATED]` flag
/// wherever prequential coverage has drifted off nominal.
fn cmd_top(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7471").to_string();
    let interval_ms: u64 = args.get_parsed_or("interval", 2_000u64)?;
    let once = args.has_flag("once");
    let mut client =
        Client::connect(&addr).with_context(|| format!("connecting to server at {addr}"))?;
    loop {
        let text = client.metricsx().context("server does not speak `metricsx` (v7)")?;
        let samples = export::parse(&text)?;
        let stats = client.stats()?;
        if !once {
            // ANSI clear + home: a refreshing dashboard, not a scrolling log.
            print!("\x1b[2J\x1b[H");
        }
        render_top(&addr, &samples, &stats);
        if once {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(interval_ms.max(100)));
    }
}

/// One dashboard frame from parsed exposition samples.
fn render_top(addr: &str, samples: &[export::Sample], stats: &str) {
    let val = |name: &str| samples.iter().find(|s| s.name == name).map_or(0.0, |s| s.value);
    let have = |name: &str| samples.iter().any(|s| s.name == name);
    let version = samples
        .iter()
        .find(|s| s.name == "ckrig_build_info")
        .and_then(|s| s.labels.iter().find(|(k, _)| k == "version"))
        .map(|(_, v)| v.as_str())
        .unwrap_or("?");
    println!(
        "ckrig top — {addr}  v{version}  up {:.0}s  {}{}",
        val("ckrig_uptime_seconds"),
        if val("ckrig_ready") >= 1.0 { "ready" } else { "NOT READY" },
        if val("ckrig_draining") >= 1.0 { " (draining)" } else { "" },
    );
    println!(
        "reqs {:.0}  preds {:.0}  obs {:.0}  suggests {:.0}  batches {:.0}  \
         errors {:.0}  degraded {:.0}  retries {:.0}  panics {:.0}  queue {:.0} pts",
        val("ckrig_requests_total"),
        val("ckrig_predictions_total"),
        val("ckrig_observes_total"),
        val("ckrig_suggests_total"),
        val("ckrig_batches_total"),
        val("ckrig_errors_total"),
        val("ckrig_degraded_total"),
        val("ckrig_retries_total"),
        val("ckrig_panics_total"),
        val("ckrig_queue_depth_points"),
    );
    println!(
        "latency p50 {:.0}µs  p99 {:.0}µs",
        hist_percentile(samples, "ckrig_request_latency_us", 50.0),
        hist_percentile(samples, "ckrig_request_latency_us", 99.0),
    );
    if have("ckrig_shards_total") {
        println!(
            "shards {:.0}/{:.0} alive",
            val("ckrig_shards_alive"),
            val("ckrig_shards_total")
        );
    }
    if have("ckrig_wal_last_seq") {
        println!(
            "wal seq {:.0}  unsynced {:.0}",
            val("ckrig_wal_last_seq"),
            val("ckrig_wal_unsynced")
        );
    }
    let jits = val("ckrig_degeneracy_jitter_escalations_total");
    if jits > 0.0 {
        println!(
            "degeneracy: {jits:.0} jitter escalations (max {:.1e})  {:.0} factor fallbacks  \
             {:.0} floor hits  {:.0} non-finite",
            val("ckrig_degeneracy_max_jitter"),
            val("ckrig_degeneracy_factor_fallbacks_total"),
            val("ckrig_degeneracy_combiner_floor_hits_total"),
            val("ckrig_degeneracy_nonfinite_rejected_total"),
        );
    }
    if have("ckrig_slo_worst") {
        let code = |c: f64| match c as u64 {
            0 => "ok",
            1 => "warn",
            _ => "BREACH",
        };
        println!("slo: {}", code(val("ckrig_slo_worst")));
    }
    let mut models: Vec<&str> = samples
        .iter()
        .filter(|s| s.name.starts_with("ckrig_model_"))
        .filter_map(|s| s.labels.iter().find(|(k, _)| k == "model"))
        .map(|(_, v)| v.as_str())
        .collect();
    models.sort_unstable();
    models.dedup();
    let mval = |name: &str, model: &str| {
        samples
            .iter()
            .find(|s| {
                s.name == name && s.labels.iter().any(|(k, v)| k == "model" && v == model)
            })
            .map_or(0.0, |s| s.value)
    };
    if !models.is_empty() {
        println!();
        println!(
            "{:<14} {:>8} {:>8} {:>6} {:>6} {:>6}  {:^16} {:>8} {:>10} {:>9} {:>6}",
            "model",
            "points",
            "observed",
            "refits",
            "drift",
            "z2",
            "cov 90/95/99",
            "rmse",
            "refit",
            "cond",
            "slo"
        );
        for m in models {
            let flagged = mval("ckrig_model_calibration_flagged", m) >= 1.0;
            // Conditioning column from the health gauges; "-" for slots
            // whose model exposes no health report.
            let cond = if samples.iter().any(|s| {
                s.name == "ckrig_model_cond_estimate"
                    && s.labels.iter().any(|(k, v)| k == "model" && v == m)
            }) {
                format!("{:.1e}", mval("ckrig_model_cond_estimate", m))
            } else {
                "-".to_string()
            };
            let slo = if samples.iter().any(|s| {
                s.name == "ckrig_slo_status"
                    && s.labels.iter().any(|(k, v)| k == "model" && v == m)
            }) {
                match mval("ckrig_slo_status", m) as u64 {
                    0 => "ok",
                    1 => "warn",
                    _ => "BREACH",
                }
                .to_string()
            } else {
                "-".to_string()
            };
            // Refit posture: running (with elapsed wall time), last
            // completed duration, or idle before the first refit.
            let refit = if mval("ckrig_model_refit_in_flight", m) >= 1.0 {
                format!("fit {:.1}s", mval("ckrig_model_refit_running_us", m) / 1e6)
            } else {
                let last = mval("ckrig_model_last_refit_duration_us", m);
                if last > 0.0 {
                    format!("{:.1}s", last / 1e6)
                } else {
                    "idle".to_string()
                }
            };
            println!(
                "{:<14} {:>8.0} {:>8.0} {:>6.0} {:>6.2} {:>6.2}  {:.2}/{:.2}/{:.2}  {:>8.3} {:>10} {:>9} {:>6}{}",
                m,
                mval("ckrig_model_train_points", m),
                mval("ckrig_model_observed_total", m),
                mval("ckrig_model_refits_total", m),
                mval("ckrig_model_drift", m),
                mval("ckrig_model_mean_z2", m),
                mval("ckrig_model_coverage90", m),
                mval("ckrig_model_coverage95", m),
                mval("ckrig_model_coverage99", m),
                mval("ckrig_model_quality_rmse", m),
                refit,
                cond,
                slo,
                if flagged { "  [MISCALIBRATED]" } else { "" }
            );
        }
    }
    println!();
    println!("stats: {stats}");
}

/// Approximate percentile from a (single, unlabeled) exposition
/// histogram's cumulative `le=` buckets: the upper bound of the first
/// bucket whose cumulative count reaches the target rank.
fn hist_percentile(samples: &[export::Sample], name: &str, p: f64) -> f64 {
    let bucket_name = format!("{name}_bucket");
    let mut buckets: Vec<(f64, f64)> = samples
        .iter()
        .filter(|s| s.name == bucket_name)
        .filter_map(|s| {
            let le = &s.labels.iter().find(|(k, _)| k == "le")?.1;
            let bound = if le == "+Inf" { f64::INFINITY } else { le.parse().ok()? };
            Some((bound, s.value))
        })
        .collect();
    buckets.sort_by(|a, b| a.0.total_cmp(&b.0));
    let total = buckets.last().map_or(0.0, |b| b.1);
    if total <= 0.0 {
        return 0.0;
    }
    let target = (p / 100.0 * total).ceil().max(1.0);
    for (bound, cum) in &buckets {
        if *cum >= target {
            return *bound;
        }
    }
    f64::INFINITY
}

/// `ckrig doctor` — render a numerical-health report for a saved
/// artifact (`--artifact model.ck`) or a live server (`--addr
/// host:port`). Exits non-zero when conditioning is critical or an SLO
/// is in breach; escalated jitter alone is a warning, not a failure.
fn cmd_doctor(args: &Args) -> Result<()> {
    match (args.get("artifact"), args.get("addr")) {
        (Some(path), None) => doctor_artifact(path),
        (None, Some(addr)) => doctor_addr(addr),
        _ => bail!("usage: ckrig doctor --artifact model.ck | --addr host:port"),
    }
}

fn doctor_artifact(path: &str) -> Result<()> {
    let model = SurrogateSpec::load_path(path)?;
    println!("ckrig doctor — artifact {path} ({})", model.name());
    let Some(report) = model.health_report() else {
        // Composition without stored factors (e.g. an empty shard):
        // nothing to diagnose, and nothing wrong either.
        println!("model exposes no health report");
        return Ok(());
    };
    render_health_report(&report);
    let worst = report.worst_class();
    println!("verdict     : {worst}");
    anyhow::ensure!(
        worst != HealthClass::Critical,
        "doctor: conditioning is critical (estimate past 1e12 — predictions \
         carry at most a few significant digits)"
    );
    Ok(())
}

/// Per-cluster conditioning table + aggregates for one health report.
fn render_health_report(report: &HealthReport) {
    println!(
        "{:<8} {:>8} {:>13} {:>13} {:>9}",
        "cluster", "points", "cond(1-norm)", "jitter", "class"
    );
    for c in &report.clusters {
        println!(
            "{:<8} {:>8} {:>13.3e} {:>13.3e} {:>9}",
            c.cluster,
            c.health.n,
            c.health.cond_estimate,
            c.health.jitter,
            c.health.class()
        );
    }
    println!(
        "clusters    : {} ({} points, balance {:.2})",
        report.clusters.len(),
        report.total_points(),
        report.balance()
    );
    let jitter_note = if report.max_jitter() > 0.0 {
        "  — escalated jitter: the correlation matrix was not PD as given"
    } else {
        ""
    };
    println!("max cond    : {:.3e}", report.max_cond());
    println!("max jitter  : {:.3e}{jitter_note}", report.max_jitter());
}

fn doctor_addr(addr: &str) -> Result<()> {
    let mut client =
        Client::connect(addr).with_context(|| format!("connecting to server at {addr}"))?;
    let text = client.metricsx().context("server does not speak `metricsx` (v7)")?;
    let samples = export::parse(&text)?;
    let stats = client.stats()?;
    let val = |name: &str| samples.iter().find(|s| s.name == name).map_or(0.0, |s| s.value);
    let have = |name: &str| samples.iter().any(|s| s.name == name);
    let mval = |name: &str, model: &str| {
        samples
            .iter()
            .find(|s| {
                s.name == name && s.labels.iter().any(|(k, v)| k == "model" && v == model)
            })
            .map_or(0.0, |s| s.value)
    };

    println!("ckrig doctor — server {addr} (up {:.0}s)", val("ckrig_uptime_seconds"));
    println!();
    println!("degeneracy counters");
    println!("  jitter escalations  : {:.0}", val("ckrig_degeneracy_jitter_escalations_total"));
    println!(
        "  jitter last/max     : {:.3e} / {:.3e}{}",
        val("ckrig_degeneracy_last_jitter"),
        val("ckrig_degeneracy_max_jitter"),
        if val("ckrig_degeneracy_max_jitter") > 0.0 { "  (escalated jitter)" } else { "" },
    );
    println!("  factor fallbacks    : {:.0}", val("ckrig_degeneracy_factor_fallbacks_total"));
    println!(
        "  combiner floor hits : {:.0}",
        val("ckrig_degeneracy_combiner_floor_hits_total")
    );
    println!(
        "  non-finite rejected : {:.0}",
        val("ckrig_degeneracy_nonfinite_rejected_total")
    );
    println!(
        "  nugget boundary hits: {:.0}",
        val("ckrig_degeneracy_nugget_boundary_hits_total")
    );

    // Per-model conditioning, from the health gauge families.
    let mut models: Vec<&str> = samples
        .iter()
        .filter(|s| s.name == "ckrig_model_cond_estimate")
        .filter_map(|s| s.labels.iter().find(|(k, _)| k == "model"))
        .map(|(_, v)| v.as_str())
        .collect();
    models.sort_unstable();
    models.dedup();
    let mut worst_health = 0.0f64;
    if !models.is_empty() {
        println!();
        println!("{:<14} {:>13} {:>13} {:>9}", "model", "cond(1-norm)", "jitter", "class");
        for m in &models {
            let class_code = mval("ckrig_model_health_class", m);
            worst_health = worst_health.max(class_code);
            let class = match class_code as u64 {
                0 => "ok",
                1 => "warn",
                _ => "critical",
            };
            println!(
                "{:<14} {:>13.3e} {:>13.3e} {:>9}",
                m,
                mval("ckrig_model_cond_estimate", m),
                mval("ckrig_model_jitter", m),
                class
            );
        }
    }

    if have("ckrig_wal_last_seq") {
        println!();
        println!(
            "wal         : seq {:.0}, {:.0} unsynced (durability lag)",
            val("ckrig_wal_last_seq"),
            val("ckrig_wal_unsynced")
        );
    }
    if have("ckrig_shards_total") {
        println!(
            "shards      : {:.0}/{:.0} alive",
            val("ckrig_shards_alive"),
            val("ckrig_shards_total")
        );
    }

    println!();
    let slo_breach = if have("ckrig_slo_worst") {
        let code = |c: f64| match c as u64 {
            0 => "ok",
            1 => "warn",
            _ => "breach",
        };
        println!("slo         : worst {}", code(val("ckrig_slo_worst")));
        let mut slo_models: Vec<&str> = samples
            .iter()
            .filter(|s| s.name == "ckrig_slo_status")
            .filter_map(|s| s.labels.iter().find(|(k, _)| k == "model"))
            .map(|(_, v)| v.as_str())
            .collect();
        slo_models.sort_unstable();
        slo_models.dedup();
        for m in &slo_models {
            println!("  {m:<12}: {}", code(mval("ckrig_slo_status", m)));
        }
        val("ckrig_slo_worst") >= 2.0
    } else {
        println!("slo         : off (serve with --slo to enable alerting)");
        false
    };
    println!("stats: {stats}");

    anyhow::ensure!(!slo_breach, "doctor: SLO breach");
    anyhow::ensure!(worst_health < 2.0, "doctor: conditioning is critical");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    match cluster_kriging::runtime::PjrtRuntime::load(dir) {
        Ok(rt) => {
            println!("PJRT platform : {}", rt.platform());
            println!("artifact dir  : {dir}");
            println!("complete buckets (n, d):");
            for (n, d) in rt.registry().complete_buckets() {
                println!("  n={n:<6} d={d}");
            }
        }
        Err(e) => {
            println!("PJRT runtime unavailable: {e:#}");
            println!("(native backend remains fully functional)");
        }
    }
    Ok(())
}
