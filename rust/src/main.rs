//! `ckrig` — the Cluster Kriging coordinator CLI.
//!
//! Subcommands:
//!   experiment  regenerate the paper's tables/figure data
//!   fit         fit one flavor on a dataset and score a holdout
//!   serve       start the TCP prediction server on a fitted model
//!   info        show PJRT platform + discovered artifacts

use anyhow::{bail, Context, Result};
use cluster_kriging::cluster_kriging::{builder, ClusterKriging};
use cluster_kriging::coordinator::{BatcherConfig, Server, ServerConfig};
use cluster_kriging::data::functions;
use cluster_kriging::data::synthetic::from_benchmark;
use cluster_kriging::data::{uci_like, Dataset};
use cluster_kriging::eval::experiments::{run_all, ExperimentConfig};
use cluster_kriging::eval::report::{self, PaperTable};
use cluster_kriging::eval::HarnessConfig;
use cluster_kriging::kriging::{HyperOpt, Surrogate};
use cluster_kriging::metrics;
use cluster_kriging::util::cli::Args;
use std::sync::Arc;

fn main() {
    env_logger_lite();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e:#}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("experiment") => cmd_experiment(&args),
        Some("fit") => cmd_fit(&args),
        Some("serve") => cmd_serve(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "ckrig — Cluster Kriging (van Stein et al., 2017)\n\
         \n\
         USAGE: ckrig <experiment|fit|serve|info> [options]\n\
         \n\
         experiment --table 1|2|3 | --figure 2 [--paper-scale] [--folds N]\n\
         \u{20}          [--datasets a,b] [--algos SoD,MTCK] [--out results/]\n\
         fit        --dataset <name> --flavor OWCK|OWFCK|GMMCK|MTCK --k K [--seed S]\n\
         serve      --dataset <name> --flavor F --k K [--addr host:port]\n\
         info       [--artifacts DIR]\n\
         \n\
         datasets: concrete ccpp sarcos ackley schaffer schwefel rast h1\n\
         \u{20}         rosenbrock himmelblau diffpow"
    );
}

/// Resolve a dataset name to generated data (paper regimes).
fn load_dataset(name: &str, seed: u64, n_override: Option<usize>) -> Result<Dataset> {
    let ds = match name {
        "concrete" => uci_like::concrete_sized(n_override.unwrap_or(1030), seed),
        "ccpp" => uci_like::ccpp_sized(n_override.unwrap_or(9568), seed),
        "sarcos" => uci_like::sarcos(seed, 0.09).0,
        other => {
            let b = functions::by_name(other)
                .with_context(|| format!("unknown dataset {other:?}"))?;
            from_benchmark(b, n_override.unwrap_or(2000), 20, 0.0, seed)
        }
    };
    Ok(ds)
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let table: Option<usize> = args.get_parsed_or("table", 0).ok().filter(|&t| t > 0);
    let figure: Option<usize> = args.get_parsed_or("figure", 0).ok().filter(|&f| f > 0);
    if table.is_none() && figure.is_none() {
        bail!("pass --table 1|2|3 or --figure 2 (or both)");
    }
    let cfg = ExperimentConfig {
        paper_scale: args.has_flag("paper-scale"),
        folds: args.get_parsed_or("folds", 3)?,
        harness: if args.has_flag("full-hyperopt") {
            HarnessConfig::default()
        } else {
            HarnessConfig::fast()
        },
        seed: args.get_parsed_or("seed", 0xE8u64)?,
        only_datasets: args.get_list::<String>("datasets")?.unwrap_or_default(),
        only_algos: args.get_list::<String>("algos")?.unwrap_or_default(),
    };
    eprintln!(
        "running experiment grid (paper_scale={}, folds={})…",
        cfg.paper_scale, cfg.folds
    );
    let grids = run_all(&cfg)?;

    let out_dir = args.get_or("out", "results");
    std::fs::create_dir_all(out_dir).ok();

    if let Some(t) = table {
        // Tables II/III are free projections of the same grid — always
        // persist all three; print the requested one.
        let requested = match t {
            1 => PaperTable::R2,
            2 => PaperTable::Msll,
            3 => PaperTable::Smse,
            _ => bail!("--table must be 1, 2 or 3"),
        };
        for (idx, pt) in
            [(1, PaperTable::R2), (2, PaperTable::Msll), (3, PaperTable::Smse)]
        {
            let md = report::render_table(&grids, pt);
            if pt == requested {
                println!("{md}");
            }
            let path = format!("{out_dir}/table{idx}.md");
            std::fs::write(&path, &md)?;
            eprintln!("wrote {path}");
        }
    }
    if let Some(f) = figure {
        if f != 2 {
            bail!("--figure must be 2");
        }
        let csv = report::fig2_csv(&grids);
        let path = format!("{out_dir}/fig2.csv");
        std::fs::write(&path, &csv)?;
        eprintln!("wrote {path} ({} rows)", csv.lines().count() - 1);
    }
    Ok(())
}

fn fit_flavor(
    ds: &Dataset,
    flavor: &str,
    k: usize,
    seed: u64,
) -> Result<(StandardizedModel, Dataset)> {
    let (train, test) = ds.split(0.8, seed);
    // Standardize on the training fold (as the evaluation harness does) —
    // the θ search bounds assume unit-scale inputs.
    let std = cluster_kriging::data::Standardizer::fit(&train);
    let tr = std.transform(&train);
    let opt = HyperOpt {
        restarts: 1,
        max_evals: 20,
        isotropic: tr.d() > 8,
        ..HyperOpt::default()
    };
    let flavor_static = builder::FLAVORS
        .iter()
        .find(|f| **f == flavor)
        .with_context(|| format!("unknown flavor {flavor:?} (expected {:?})", builder::FLAVORS))?;
    let cfg = builder::flavor(flavor_static, k, seed, opt)?;
    let model = ClusterKriging::fit(&tr.x, &tr.y, cfg)?;
    Ok((StandardizedModel { inner: model, std }, test))
}

/// A fitted model plus the train-fold standardizer; predictions are
/// mapped back to the original target scale.
struct StandardizedModel {
    inner: ClusterKriging,
    std: cluster_kriging::data::Standardizer,
}

impl StandardizedModel {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn cluster_sizes(&self) -> &[usize] {
        &self.inner.cluster_sizes
    }
}

impl Surrogate for StandardizedModel {
    fn predict(&self, xt: &cluster_kriging::util::Matrix) -> Result<cluster_kriging::kriging::Prediction> {
        // Standardize features, predict, de-standardize outputs.
        let ds = Dataset::new("query", xt.clone(), vec![0.0; xt.rows()]);
        let t = self.std.transform(&ds);
        let pred = self.inner.predict(&t.x)?;
        Ok(cluster_kriging::kriging::Prediction {
            mean: pred.mean.iter().map(|&v| self.std.inverse_y(v)).collect(),
            variance: pred.variance.iter().map(|&v| self.std.inverse_var(v)).collect(),
        })
    }

    fn name(&self) -> &str {
        self.inner.name()
    }
}

fn cmd_fit(args: &Args) -> Result<()> {
    let dataset: String = args.require("dataset")?;
    let flavor: String = args.require("flavor")?;
    let k: usize = args.get_parsed_or("k", 4)?;
    let seed: u64 = args.get_parsed_or("seed", 1)?;
    let n: Option<usize> = args.get_parsed_or("n", 0).ok().filter(|&n| n > 0);

    let ds = load_dataset(&dataset, seed, n)?;
    eprintln!("dataset {} ({}×{}), flavor {flavor}, k={k}", ds.name, ds.n(), ds.d());
    let t0 = std::time::Instant::now();
    let (model, test) = fit_flavor(&ds, &flavor, k, seed)?;
    let fit_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let pred = model.predict(&test.x)?;
    let pred_s = t1.elapsed().as_secs_f64();

    println!("flavor      : {}", model.name());
    println!("clusters    : {:?}", model.cluster_sizes());
    println!("fit_seconds : {fit_s:.3}");
    println!("pred_seconds: {pred_s:.3}");
    println!("R2          : {:.4}", metrics::r2(&test.y, &pred.mean));
    println!("SMSE        : {:.4}", metrics::smse(&test.y, &pred.mean));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dataset: String = args.require("dataset")?;
    let flavor: String = args.get_or("flavor", "MTCK").to_string();
    let k: usize = args.get_parsed_or("k", 4)?;
    let seed: u64 = args.get_parsed_or("seed", 1)?;
    let addr = args.get_or("addr", "127.0.0.1:7471").to_string();
    let n: Option<usize> = args.get_parsed_or("n", 0).ok().filter(|&n| n > 0);

    let ds = load_dataset(&dataset, seed, n)?;
    let dim = ds.d();
    eprintln!("fitting {flavor} (k={k}) on {} ({}×{dim})…", ds.name, ds.n());
    let (model, _) = fit_flavor(&ds, &flavor, k, seed)?;
    let model: Arc<dyn Surrogate> = Arc::new(model);
    let server =
        Server::start(model, ServerConfig { addr, batcher: BatcherConfig::default(), dim })?;
    println!(
        "serving on {} — protocol: `predict x1,...,x{dim}` | `stats` | `ping`",
        server.local_addr
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(5));
        eprintln!("{}", server.metrics.summary());
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    match cluster_kriging::runtime::PjrtRuntime::load(dir) {
        Ok(rt) => {
            println!("PJRT platform : {}", rt.platform());
            println!("artifact dir  : {dir}");
            println!("complete buckets (n, d):");
            for (n, d) in rt.registry().complete_buckets() {
                println!("  n={n:<6} d={d}");
            }
        }
        Err(e) => {
            println!("PJRT runtime unavailable: {e:#}");
            println!("(native backend remains fully functional)");
        }
    }
    Ok(())
}

/// Tiny env_logger substitute: honors RUST_LOG=debug|info|warn.
fn env_logger_lite() {
    struct L(log::LevelFilter);
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= self.0
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("info") => log::LevelFilter::Info,
        Ok("warn") => log::LevelFilter::Warn,
        _ => log::LevelFilter::Error,
    };
    let _ = log::set_boxed_logger(Box::new(L(level)));
    log::set_max_level(level);
}
