//! Named model slots with atomic hot swap — the serving layer's model
//! store.
//!
//! The registry maps slot names to `Arc<dyn Surrogate>`. Replacing a slot
//! ([`ModelRegistry::insert`]) or retargeting the default
//! ([`ModelRegistry::set_default`]) swaps an `Arc` under a write lock
//! held for nanoseconds; readers ([`ModelRegistry::get`]) clone the `Arc`
//! out and predict lock-free, so in-flight batches finish on the model
//! they resolved while new batches see the replacement — hot swap under
//! live traffic with no draining, no restart.

use crate::kriging::Surrogate;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

/// One row of [`ModelRegistry::list`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelInfo {
    pub name: String,
    pub algo: String,
    pub dim: usize,
    pub is_default: bool,
}

/// Slot map + default pointer behind ONE lock, so every check-then-act
/// operation (swap, remove-unless-default) is atomic and the invariant
/// "the default name always resolves" cannot be raced away.
struct Inner {
    slots: HashMap<String, Arc<dyn Surrogate>>,
    default_name: String,
}

/// Thread-safe registry of named, hot-swappable model slots. There is
/// always at least one slot, and the default name always resolves.
pub struct ModelRegistry {
    inner: RwLock<Inner>,
}

impl ModelRegistry {
    /// Create a registry with one initial slot, which becomes the default.
    pub fn new(name: impl Into<String>, model: Arc<dyn Surrogate>) -> Self {
        let name = name.into();
        let mut slots: HashMap<String, Arc<dyn Surrogate>> = HashMap::new();
        slots.insert(name.clone(), model);
        Self { inner: RwLock::new(Inner { slots, default_name: name }) }
    }

    /// Insert or atomically replace a slot. Readers holding the previous
    /// `Arc` keep serving it until their batch completes.
    pub fn insert(&self, name: impl Into<String>, model: Arc<dyn Surrogate>) {
        self.inner.write().unwrap().slots.insert(name.into(), model);
    }

    /// Resolve a slot: `None` means the current default.
    pub fn get(&self, name: Option<&str>) -> Option<Arc<dyn Surrogate>> {
        let inner = self.inner.read().unwrap();
        inner.slots.get(name.unwrap_or(&inner.default_name)).cloned()
    }

    /// The current default model (always present by construction).
    pub fn default_model(&self) -> Arc<dyn Surrogate> {
        self.get(None).expect("registry default slot missing")
    }

    pub fn default_name(&self) -> String {
        self.inner.read().unwrap().default_name.clone()
    }

    /// Retarget the default at an existing slot (the `swap` protocol op).
    pub fn set_default(&self, name: &str) -> Result<()> {
        let mut inner = self.inner.write().unwrap();
        if !inner.slots.contains_key(name) {
            bail!("no model slot named {name:?}");
        }
        inner.default_name = name.to_string();
        Ok(())
    }

    /// Remove a non-default slot.
    pub fn remove(&self, name: &str) -> Result<()> {
        let mut inner = self.inner.write().unwrap();
        if inner.default_name == name {
            bail!("cannot remove the default slot {name:?}; swap first");
        }
        if inner.slots.remove(name).is_none() {
            bail!("no model slot named {name:?}");
        }
        Ok(())
    }

    /// Whether a slot with this name exists right now.
    pub fn contains(&self, name: &str) -> bool {
        self.inner.read().unwrap().slots.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of all slots, sorted by name.
    pub fn list(&self) -> Vec<ModelInfo> {
        let inner = self.inner.read().unwrap();
        let mut out: Vec<ModelInfo> = inner
            .slots
            .iter()
            .map(|(name, model)| ModelInfo {
                name: name.clone(),
                algo: model.name().to_string(),
                dim: model.dim(),
                is_default: *name == inner.default_name,
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kriging::Prediction;
    use crate::util::matrix::Matrix;

    struct Constant(f64);
    impl Surrogate for Constant {
        fn predict(&self, xt: &Matrix) -> Result<Prediction> {
            Ok(Prediction { mean: vec![self.0; xt.rows()], variance: vec![0.0; xt.rows()] })
        }
        fn name(&self) -> &str {
            "const"
        }
        fn dim(&self) -> usize {
            2
        }
    }

    fn probe(model: &dyn Surrogate) -> f64 {
        model.predict(&Matrix::zeros(1, 2)).unwrap().mean[0]
    }

    #[test]
    fn default_resolves_and_swaps() {
        let reg = ModelRegistry::new("v1", Arc::new(Constant(1.0)));
        assert_eq!(probe(&*reg.default_model()), 1.0);
        reg.insert("v2", Arc::new(Constant(2.0)));
        // Default unchanged until the explicit swap.
        assert_eq!(probe(&*reg.default_model()), 1.0);
        assert_eq!(reg.len(), 2);
        reg.set_default("v2").unwrap();
        assert_eq!(probe(&*reg.default_model()), 2.0);
        assert_eq!(reg.default_name(), "v2");
        // Named lookups see both.
        assert_eq!(probe(&*reg.get(Some("v1")).unwrap()), 1.0);
        assert!(reg.get(Some("missing")).is_none());
    }

    #[test]
    fn swap_to_missing_slot_rejected() {
        let reg = ModelRegistry::new("v1", Arc::new(Constant(1.0)));
        assert!(reg.set_default("nope").is_err());
        assert_eq!(reg.default_name(), "v1");
    }

    #[test]
    fn in_flight_arc_survives_replacement() {
        let reg = ModelRegistry::new("m", Arc::new(Constant(1.0)));
        let held = reg.default_model();
        reg.insert("m", Arc::new(Constant(9.0)));
        // The held handle still serves the old model; fresh resolution
        // sees the replacement.
        assert_eq!(probe(&*held), 1.0);
        assert_eq!(probe(&*reg.default_model()), 9.0);
    }

    #[test]
    fn remove_guards_default() {
        let reg = ModelRegistry::new("a", Arc::new(Constant(1.0)));
        reg.insert("b", Arc::new(Constant(2.0)));
        assert!(reg.remove("a").is_err());
        reg.remove("b").unwrap();
        assert!(reg.remove("b").is_err());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn list_is_sorted_and_marks_default() {
        let reg = ModelRegistry::new("zeta", Arc::new(Constant(1.0)));
        reg.insert("alpha", Arc::new(Constant(2.0)));
        let infos = reg.list();
        assert_eq!(infos.len(), 2);
        assert_eq!(infos[0].name, "alpha");
        assert!(!infos[0].is_default);
        assert!(infos[1].is_default);
        assert_eq!(infos[1].algo, "const");
        assert_eq!(infos[1].dim, 2);
    }
}
