//! [`ShardPool`]: persistent connections from a scatter-gather
//! coordinator to its shard workers.
//!
//! One [`Client`] per shard, kept open across requests (connection setup
//! is pure latency on the fan-out path) with per-request socket
//! deadlines so a dead worker costs one timeout, never a hang. Failure
//! handling is the pool's whole job:
//!
//! * a send/receive error or timeout marks the shard **dead** and the
//!   in-flight fan-out simply proceeds without it (the caller merges the
//!   survivors — see
//!   [`Combiner::merge_partial`][crate::cluster_kriging::Combiner::merge_partial]);
//! * every degraded merge ticks the pool's `degraded` counter and the
//!   attached [`ServerMetrics`], so operators see partial answers in
//!   `stats` instead of silently-wider posteriors;
//! * a background thread retries the connection with backoff and
//!   revalidates the worker's `shardinfo` (same clusters, same
//!   dimension) before marking it alive again — a wrong or restarted-
//!   with-a-different-artifact worker stays dead.

use crate::coordinator::metrics::ServerMetrics;
use crate::coordinator::server::{Client, Health};
use crate::distributed::ShardManifest;
use crate::obs::trace::{self, WireSpan};
use crate::util::matrix::Matrix;
use anyhow::{ensure, Context, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct ShardPoolConfig {
    /// TCP connect deadline per attempt.
    pub connect_timeout: Duration,
    /// Read/write socket deadline per request — the fan-out's worst-case
    /// added latency when a shard dies mid-response.
    pub request_timeout: Duration,
    /// Pause between background reconnection attempts.
    pub retry_backoff: Duration,
}

impl Default for ShardPoolConfig {
    fn default() -> Self {
        Self {
            connect_timeout: Duration::from_secs(1),
            request_timeout: Duration::from_secs(5),
            retry_backoff: Duration::from_millis(500),
        }
    }
}

struct Endpoint {
    index: usize,
    addr: String,
    expected_clusters: Vec<usize>,
    conn: Mutex<Option<Client>>,
    alive: AtomicBool,
    reconnecting: AtomicBool,
}

/// Persistent, self-healing connections to one sharded deployment.
pub struct ShardPool {
    endpoints: Vec<Arc<Endpoint>>,
    cfg: ShardPoolConfig,
    dim: usize,
    /// Scatter-gather merges that dropped ≥ 1 shard.
    degraded: AtomicU64,
    /// Immediate same-request retries after a transport failure
    /// (successful or not — the attempt is what's counted).
    retries: AtomicU64,
    metrics: Mutex<Option<Arc<ServerMetrics>>>,
    health: Mutex<Option<Arc<Health>>>,
}

impl ShardPool {
    /// Connect to `addrs` (one per shard, in shard-index order) and
    /// validate each worker's `shardinfo` against the manifest. Workers
    /// that are down or mismatched at startup are tolerated — marked
    /// dead with background retries — but at least one must be healthy,
    /// and a *mismatched* (wrong clusters/dimension) worker is a hard
    /// error: that is a topology bug, not an outage.
    pub fn connect(
        addrs: &[String],
        manifest: &ShardManifest,
        cfg: ShardPoolConfig,
    ) -> Result<Arc<Self>> {
        ensure!(
            addrs.len() == manifest.shard_count(),
            "{} shard addresses for a {}-shard manifest",
            addrs.len(),
            manifest.shard_count()
        );
        let endpoints: Vec<Arc<Endpoint>> = addrs
            .iter()
            .enumerate()
            .map(|(index, addr)| {
                Arc::new(Endpoint {
                    index,
                    addr: addr.clone(),
                    expected_clusters: manifest.shards[index].clone(),
                    conn: Mutex::new(None),
                    alive: AtomicBool::new(false),
                    reconnecting: AtomicBool::new(false),
                })
            })
            .collect();
        let pool = Arc::new(Self {
            endpoints,
            cfg,
            dim: manifest.dim,
            degraded: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            metrics: Mutex::new(None),
            health: Mutex::new(None),
        });
        let mut healthy = 0;
        for i in 0..pool.endpoints.len() {
            match pool.dial(i) {
                Ok(mut client) => {
                    // A *reachable* worker serving the wrong clusters or
                    // dimension is a topology bug, not an outage — fail
                    // loudly instead of retrying forever.
                    pool.validate(i, &mut client).with_context(|| {
                        format!(
                            "shard {i} at {} does not match the manifest",
                            pool.endpoints[i].addr
                        )
                    })?;
                    *pool.endpoints[i].conn.lock().unwrap() = Some(client);
                    pool.endpoints[i].alive.store(true, Ordering::SeqCst);
                    healthy += 1;
                }
                Err(e) => {
                    log::warn!(
                        "shard {i} at {} unavailable at startup ({e:#}); will retry",
                        pool.endpoints[i].addr
                    );
                    pool.schedule_reconnect(i);
                }
            }
        }
        ensure!(healthy > 0, "no shard worker reachable at startup");
        Ok(pool)
    }

    /// Wire the server metrics so degraded merges show up in `stats`.
    pub fn attach_metrics(&self, metrics: Arc<ServerMetrics>) {
        *self.metrics.lock().unwrap() = Some(metrics);
    }

    /// Wire a [`Health`] endpoint so shard liveness shows up in the
    /// coordinator's `health` replies. Seeds the totals immediately.
    pub fn attach_health(&self, health: Arc<Health>) {
        health.shards_total.store(self.endpoints.len() as u64, Ordering::Relaxed);
        *self.health.lock().unwrap() = Some(health);
        self.refresh_health();
    }

    /// Mirror the current down-shard count into the attached health
    /// endpoint (no-op until [`Self::attach_health`]).
    fn refresh_health(&self) {
        if let Some(h) = self.health.lock().unwrap().as_ref() {
            let down =
                self.endpoints.iter().filter(|e| !e.alive.load(Ordering::SeqCst)).count();
            h.shards_down.store(down as u64, Ordering::Relaxed);
        }
    }

    pub fn shard_count(&self) -> usize {
        self.endpoints.len()
    }

    /// Liveness snapshot, per shard index.
    pub fn alive(&self) -> Vec<bool> {
        self.endpoints.iter().map(|e| e.alive.load(Ordering::SeqCst)).collect()
    }

    pub fn alive_count(&self) -> usize {
        self.alive().into_iter().filter(|&a| a).count()
    }

    /// Merges that had to drop ≥ 1 shard, over the pool's lifetime.
    pub fn degraded_merges(&self) -> u64 {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Immediate retries attempted after transport failures, over the
    /// pool's lifetime.
    pub fn retried_requests(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Record one immediate retry attempt (pool counter + attached
    /// server metrics).
    fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = self.metrics.lock().unwrap().as_ref() {
            m.record_retry();
        }
    }

    /// Record one degraded merge (pool counter + attached server
    /// metrics).
    pub fn note_degraded(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = self.metrics.lock().unwrap().as_ref() {
            m.record_degraded();
        }
    }

    /// Open one worker connection with deadlines (no handshake).
    fn dial(&self, index: usize) -> Result<Client> {
        let ep = &self.endpoints[index];
        let mut client = Client::connect_with_timeout(&ep.addr, self.cfg.connect_timeout)
            .with_context(|| format!("connecting to shard {index} at {}", ep.addr))?;
        client.set_timeouts(Some(self.cfg.request_timeout), Some(self.cfg.request_timeout))?;
        Ok(client)
    }

    /// `shardinfo` handshake: the worker must serve exactly the manifest's
    /// cluster set and dimensionality.
    fn validate(&self, index: usize, client: &mut Client) -> Result<()> {
        let ep = &self.endpoints[index];
        let info = client
            .shard_info(None)
            .with_context(|| format!("handshaking shard {index} at {}", ep.addr))?;
        ensure!(
            info.clusters == ep.expected_clusters,
            "cluster-set mismatch: shard {index} serves {:?}, manifest expects {:?}",
            info.clusters,
            ep.expected_clusters
        );
        ensure!(
            info.dim == self.dim,
            "dimension mismatch: shard {index} serves d={}, manifest expects d={}",
            info.dim,
            self.dim
        );
        Ok(())
    }

    /// Mark a shard dead after a request failure and kick off background
    /// recovery.
    fn mark_dead(self: &Arc<Self>, index: usize, why: &anyhow::Error) {
        let ep = &self.endpoints[index];
        if ep.alive.swap(false, Ordering::SeqCst) {
            log::warn!("shard {index} at {} marked dead: {why:#}", ep.addr);
        }
        *ep.conn.lock().unwrap() = None;
        self.refresh_health();
        self.schedule_reconnect(index);
    }

    /// Spawn (at most one) background reconnector for a dead shard. The
    /// thread holds only a `Weak` pool reference, so dropping the pool
    /// ends recovery instead of leaking a retry loop forever.
    fn schedule_reconnect(self: &Arc<Self>, index: usize) {
        let ep = &self.endpoints[index];
        if ep.reconnecting.swap(true, Ordering::SeqCst) {
            return;
        }
        let weak: Weak<ShardPool> = Arc::downgrade(self);
        let backoff = self.cfg.retry_backoff;
        std::thread::spawn(move || loop {
            std::thread::sleep(backoff);
            let Some(pool) = weak.upgrade() else { return };
            let ep = &pool.endpoints[index];
            // Revalidate on every reconnect: a worker restarted with the
            // wrong artifact must stay dead, not silently rejoin.
            match pool.dial(index).and_then(|mut c| {
                pool.validate(index, &mut c)?;
                Ok(c)
            }) {
                Ok(client) => {
                    *ep.conn.lock().unwrap() = Some(client);
                    ep.alive.store(true, Ordering::SeqCst);
                    ep.reconnecting.store(false, Ordering::SeqCst);
                    pool.refresh_health();
                    log::info!("shard {index} at {} reconnected", ep.addr);
                    return;
                }
                Err(e) => {
                    log::debug!("shard {index} reconnect attempt failed: {e:#}");
                }
            }
        });
    }

    /// `spredict` against one shard. A transport failure marks the shard
    /// dead (background recovery starts) and surfaces as an error the
    /// caller treats as "this shard contributed nothing".
    ///
    /// When the calling thread carries an ambient trace context
    /// ([`trace::current`]), the trace ID rides the wire (`trace=` on
    /// `spredict`, so the worker records its server-side spans under the
    /// same trace) and the full round trip is recorded coordinator-side
    /// as a `shard-<i>-rtt` span — the gap between RTT and the worker's
    /// own `spredict` span is network + queueing.
    pub fn shard_predict(
        self: &Arc<Self>,
        index: usize,
        xt: &Matrix,
        filter: Option<&[usize]>,
    ) -> Result<Vec<Vec<(usize, f64, f64)>>> {
        let Some(ctx) = trace::current() else {
            return self.shard_predict_wire(index, xt, filter, None);
        };
        let start = ctx.tracer.now_us();
        let out = self.shard_predict_wire(index, xt, filter, Some(ctx.trace_id));
        let dur = ctx.tracer.now_us().saturating_sub(start);
        ctx.record(&format!("shard-{index}-rtt"), start, dur);
        out
    }

    /// The wire leg of [`Self::shard_predict`]: pooled connection,
    /// liveness bookkeeping, immediate retry.
    fn shard_predict_wire(
        self: &Arc<Self>,
        index: usize,
        xt: &Matrix,
        filter: Option<&[usize]>,
        trace_id: Option<u64>,
    ) -> Result<Vec<Vec<(usize, f64, f64)>>> {
        let ep = &self.endpoints[index];
        let mut guard = ep.conn.lock().unwrap();
        let client = guard
            .as_mut()
            .with_context(|| format!("shard {index} at {} is down", ep.addr))?;
        match client.shard_predict_traced(None, xt, filter, trace_id) {
            Ok(rows) => {
                ensure!(
                    rows.len() == xt.rows(),
                    "shard {index} answered {} rows for {} points",
                    rows.len(),
                    xt.rows()
                );
                Ok(rows)
            }
            Err(e) => {
                // An `err …` protocol reply is the worker *rejecting* the
                // request over a healthy, still-in-sync connection (e.g. a
                // hot-swapped slot that transiently lost its cluster
                // decomposition) — this fan-out proceeds without the
                // shard, but the connection is NOT an outage. Only
                // transport-level failures (closed/timed-out socket,
                // garbled reply) poison the shard.
                if e.to_string().contains("server error:") {
                    Err(e.context(format!("shard {index} rejected the request")))
                } else {
                    // Transport failure. `spredict` is idempotent, so try
                    // once more against a freshly dialed (and revalidated)
                    // connection before declaring an outage: a single
                    // dropped connection or worker restart heals here for
                    // the cost of one reconnect, instead of a degraded
                    // merge plus the background backoff loop.
                    drop(guard);
                    self.note_retry();
                    match self.redial_and_predict(index, xt, filter, trace_id) {
                        Ok(rows) => {
                            log::info!(
                                "shard {index} at {} recovered on immediate retry",
                                ep.addr
                            );
                            Ok(rows)
                        }
                        Err(retry_err) => {
                            self.mark_dead(index, &retry_err);
                            Err(e.context(format!(
                                "shard {index} at {} failed (retry: {retry_err:#})",
                                ep.addr
                            )))
                        }
                    }
                }
            }
        }
    }

    /// The immediate-retry leg of [`Self::shard_predict`]: fresh dial,
    /// full `shardinfo` revalidation (a restarted-with-the-wrong-artifact
    /// worker must not sneak back in), one request. On success the fresh
    /// connection replaces the poisoned one and the shard stays alive.
    fn redial_and_predict(
        self: &Arc<Self>,
        index: usize,
        xt: &Matrix,
        filter: Option<&[usize]>,
        trace_id: Option<u64>,
    ) -> Result<Vec<Vec<(usize, f64, f64)>>> {
        let ep = &self.endpoints[index];
        let mut client = self.dial(index)?;
        self.validate(index, &mut client)?;
        let rows = client.shard_predict_traced(None, xt, filter, trace_id)?;
        ensure!(
            rows.len() == xt.rows(),
            "shard {index} answered {} rows for {} points",
            rows.len(),
            xt.rows()
        );
        *ep.conn.lock().unwrap() = Some(client);
        ep.alive.store(true, Ordering::SeqCst);
        self.refresh_health();
        Ok(rows)
    }

    /// Fan one batch out to every live shard concurrently; `None` marks
    /// a shard that was dead or failed mid-request (and is now
    /// recovering in the background).
    ///
    /// The calling thread's ambient trace context (if any) is cloned
    /// into every scatter thread — thread-locals do not cross
    /// [`std::thread::scope`] on their own — so per-shard RTT spans and
    /// the on-the-wire trace ID survive the fan-out.
    pub fn scatter(self: &Arc<Self>, xt: &Matrix) -> Vec<Option<Vec<Vec<(usize, f64, f64)>>>> {
        let ctx = trace::current();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..self.endpoints.len())
                .map(|i| {
                    let ctx = ctx.clone();
                    scope.spawn(move || {
                        let _guard = ctx.map(trace::enter);
                        self.shard_predict(i, xt, None).ok()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("scatter worker panicked")).collect()
        })
    }

    /// Gather retained spans for `trace_id` from every live shard worker
    /// (protocol v7 `trace` op), relabeling each span's process from the
    /// worker's own `local` to `shard-<i>`. Best-effort diagnostics: a
    /// shard that is down or fails the request contributes nothing, and
    /// is **not** marked dead over it — tracing must never take a
    /// serving connection down.
    pub fn collect_trace(&self, trace_id: u64) -> Vec<WireSpan> {
        let mut out = Vec::new();
        for ep in &self.endpoints {
            if !ep.alive.load(Ordering::SeqCst) {
                continue;
            }
            let mut guard = ep.conn.lock().unwrap();
            let Some(client) = guard.as_mut() else { continue };
            match client.trace_spans(trace_id) {
                Ok(spans) => out.extend(spans.into_iter().map(|mut w| {
                    w.proc = format!("shard-{}", ep.index);
                    w
                })),
                Err(e) => {
                    log::debug!("trace collection from shard {} failed: {e:#}", ep.index);
                }
            }
        }
        out
    }

    /// Gather each live worker's numerical-health summary token (the
    /// `shealth=` field of the `shardinfo` reply). Best-effort
    /// diagnostics like [`Self::collect_trace`]: a shard that is down,
    /// fails the request, or predates health reporting contributes
    /// nothing, and is **not** marked dead over it.
    pub fn collect_health(&self) -> Vec<(usize, String)> {
        let mut out = Vec::new();
        for ep in &self.endpoints {
            if !ep.alive.load(Ordering::SeqCst) {
                continue;
            }
            let mut guard = ep.conn.lock().unwrap();
            let Some(client) = guard.as_mut() else { continue };
            match client.shard_info(None) {
                Ok(info) => {
                    if let Some(tok) = info.shealth {
                        out.push((ep.index, tok));
                    }
                }
                Err(e) => {
                    log::debug!("health collection from shard {} failed: {e:#}", ep.index);
                }
            }
        }
        out
    }

    /// Forward a group of observations to one shard (protocol v3
    /// `observeb` on the worker). Returns how many the worker absorbed.
    ///
    /// Unlike [`Self::shard_predict`] there is NO immediate retry here:
    /// `observeb` mutates the worker, and a timed-out request may have
    /// been applied before the connection died — re-sending it would
    /// double-count the observations. A transport failure just marks the
    /// shard dead and lets the caller decide what to do with the batch.
    pub fn observe_rows(self: &Arc<Self>, index: usize, xs: &Matrix, ys: &[f64]) -> Result<usize> {
        let ep = &self.endpoints[index];
        let mut guard = ep.conn.lock().unwrap();
        let client = guard
            .as_mut()
            .with_context(|| format!("shard {index} at {} is down", ep.addr))?;
        let points: Vec<&[f64]> = (0..xs.rows()).map(|i| xs.row(i)).collect();
        match client.observe_batch(None, &points, ys) {
            Ok(n) => Ok(n),
            Err(e) => {
                // An `err …` protocol reply is the worker *rejecting* the
                // batch (shape, capability) over a healthy connection;
                // only transport-level failures (closed/timed-out socket,
                // garbled reply) poison the shard.
                if e.to_string().contains("server error:") {
                    Err(e.context(format!("shard {index} rejected observations")))
                } else {
                    drop(guard);
                    self.mark_dead(index, &e);
                    Err(e.context(format!("shard {index} at {} failed", ep.addr)))
                }
            }
        }
    }
}
