//! TCP prediction server — the leader process of the coordinator.
//!
//! Line protocol (one request per line; [model] is an optional registry
//! slot name, defaulting to the current default slot):
//!
//!   v1 (kept verbatim):
//!   `predict <x1>,<x2>,...`          → `ok <mean>,<variance>`
//!   `stats`                          → `ok <metrics summary>`
//!   `ping`                           → `ok pong`
//!
//!   v2 (model lifecycle):
//!   `predict <model> <csv>`          → `ok <mean>,<variance>`
//!   `predictb [model] <n> <p1;p2;…>` → `ok <m1>,<v1>;<m2>,<v2>;…`
//!     (each `pi` is a CSV point; `n` must match the point count)
//!   `models`                         → `ok default=<name> <name>:<algo>:d<dim> …`
//!   `load <path> [name]`             → `ok loaded <name> <algo> d=<dim>`
//!     (server-side artifact path; slot name defaults to the file stem)
//!   `swap <name>`                    → `ok swapped <name>`
//!   anything else                    → `err <message>`
//!
//!   v3 (online learning):
//!   `observe [model] <csv>`          → `ok observed 1`
//!     (CSV carries d+1 values: the point's features, then the target)
//!   `observeb [model] <n> <o1;o2;…>` → `ok observed <n>`
//!     (each `oi` is a d+1-value CSV observation)
//!   `stats`                          → `ok <metrics> slots=<a,b,…> default=<name>`
//!     (v3 extends the v1 reply with the observes counter inside the
//!     metrics summary plus the registered model-slot names)
//!
//!   v5 (distributed cluster serving):
//!   `spredict [model] <n> <p1;p2;…> [clusters=c1,c2,…]`
//!                                    → `ok spreds <g1;g2;…>`
//!     (raw, uncombined per-cluster posteriors — what a shard worker
//!     serves to a scatter-gather coordinator. Each `gi` lists the
//!     answering clusters for point i as `c:mean,variance` entries
//!     joined by `|`, ascending by cluster id; the optional `clusters=`
//!     filter restricts evaluation to the listed clusters, as the
//!     coordinator's single-model routing does. Partials are in the
//!     serving model's FIT units — Standardized shards deliberately do
//!     not de-standardize them, so the coordinator's merge applies the
//!     combiner's variance floor in the same units the monolithic model
//!     would, and converts only the combined posterior to raw units)
//!   `shardinfo [model]`              → `ok shard <i>/<s> k=<k> d=<dim>
//!                                        clusters=<c1,c2,…> algo=<name>`
//!     (topology handshake: shard index/count — `0/1` for a monolithic
//!     ensemble — total cluster count, dimensionality and the owned
//!     cluster ids, validated by the coordinator's connection pool
//!     before the shard joins a fan-out)
//!
//!   v4 (optimization as a service):
//!   `suggest [model] <q> [bounds]`   → `ok <p1;p2;…;pq>`
//!     (propose q points to evaluate next, maximizing Expected
//!     Improvement over the slot's posterior; `bounds` is an optional
//!     `lo1,hi1;lo2,hi2;…` box, defaulting to the slot's training
//!     snapshot expanded 5% per side — the slot must be online-capable
//!     so the incumbent is known)
//!   `tell [model] <csv>`             → `ok told 1`
//!     (report an evaluated suggestion: d features then the objective
//!     value; rides the observe flush queue, so the posterior the next
//!     flush serves has absorbed it)
//!
//!   v6 (robustness / operations):
//!   `health`                         → `ok health ready=<bool> draining=<bool>
//!                                        depth=<n> panics=<n>
//!                                        [wal_seq=<n> wal_unsynced=<n>]
//!                                        [shards_alive=<a>/<t>]`
//!     (readiness + liveness for orchestrators: `draining` flips when a
//!     SIGTERM/SIGINT drain begins, `depth` is the flush-queue
//!     backpressure, `wal_*` report write-ahead-log sequence and fsync
//!     lag when the server runs with `--wal`, and `shards_alive` counts
//!     healthy shard connections on a scatter-gather coordinator)
//!
//!   v7 (observability):
//!   `metricsx`                       → Prometheus text exposition,
//!                                      terminated by a `# EOF` line
//!     (the protocol's one multi-line reply — counters, latency bucket
//!     histograms, WAL lag, shard liveness and per-model quality gauges,
//!     scrapeable with `nc`; see [`crate::obs::export`])
//!   `predictb … [trace=<hex>]`       → as v2, recording a span tree
//!     (the optional trailing token forces a trace under a client-chosen
//!     ID; without it the server's sampler decides. `spredict` accepts
//!     the same token — that is how a coordinator propagates its trace
//!     ID to shard workers)
//!   `trace <hex>`                    → `ok trace <hex> <n> <spans>`
//!     (every retained span of that trace: the local ones plus, on a
//!     coordinator, spans collected from the shard pool relabeled
//!     `shard-<i>` — one line stitching the cross-process tree)
//!   `traces`                         → `ok traces <hex>,<hex>,…`
//!     (recently retained trace IDs, most recent first)
//!   `stats`/`health` append `uptime_s=<s> started_unix=<s> version=<v>`
//!     (process identity for restart/version-skew dashboards)
//!
//! Requests funnel through the [`Batcher`], so concurrent clients are
//! served in dynamically-formed micro-batches; observations join the
//! same flush queue and apply before that flush's predictions. Models
//! live in a [`ModelRegistry`] of atomically swappable slots — `load` +
//! `swap` replace the serving model under live traffic without a
//! restart, and online slots (see [`crate::online::OnlineModel`]) absorb
//! `observe` traffic in place between swaps.

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::metrics::{ProtocolOp, ServerMetrics};
use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::shardpool::ShardPool;
use crate::kriging::Surrogate;
use crate::obs::export::{self, PromText};
use crate::obs::slo::{SloEngine, SloInputs, SloReport};
use crate::obs::trace::{self, Span, TraceCtx, Tracer, WireSpan};
use crate::online::wal::Durability;
use crate::surrogate::SurrogateSpec;
use crate::util::matrix::Matrix;
use crate::util::{faults, Rng};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

pub struct ServerConfig {
    pub addr: String,
    pub batcher: BatcherConfig,
}

/// Liveness/readiness state behind the `health` protocol op. Shared
/// between the server, the drain loop (`draining`), the WAL layer
/// (`wal_*`) and a coordinator's shard pool (`shards_*`) — all atomics,
/// so every reader is wait-free.
#[derive(Debug, Default)]
pub struct Health {
    /// Set when a graceful shutdown began: the process still answers,
    /// but orchestrators should route new traffic elsewhere.
    pub draining: AtomicBool,
    pub wal_attached: AtomicBool,
    pub wal_last_seq: AtomicU64,
    /// Appended-but-unsynced WAL records (the durability lag).
    pub wal_unsynced: AtomicU64,
    pub shards_total: AtomicU64,
    pub shards_down: AtomicU64,
}

impl Health {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Ready = not draining, and (for a coordinator) at least one shard
    /// healthy. A degraded-but-serving fleet stays ready.
    pub fn ready(&self) -> bool {
        if self.draining.load(Ordering::Relaxed) {
            return false;
        }
        let total = self.shards_total.load(Ordering::Relaxed);
        total == 0 || self.shards_down.load(Ordering::Relaxed) < total
    }

    /// Mirror the WAL counters (called from the serve loop).
    pub fn observe_wal(&self, dur: &Durability) {
        self.wal_attached.store(true, Ordering::Relaxed);
        self.wal_last_seq.store(dur.last_seq(), Ordering::Relaxed);
        self.wal_unsynced.store(dur.unsynced(), Ordering::Relaxed);
    }
}

/// Extras for [`Server::start_with_options`]: caller-owned metrics, an
/// optional write-ahead log for the observe path, the shared health
/// state the `health` op reports, the span recorder behind protocol v7
/// tracing, and — on a scatter-gather coordinator — the shard pool the
/// `trace` op collects remote spans from.
pub struct ServeOptions {
    pub metrics: Arc<ServerMetrics>,
    pub wal: Option<Arc<Durability>>,
    pub health: Arc<Health>,
    /// Span recorder for this process. Defaults to a disabled tracer
    /// (client-forced `trace=` requests still record).
    pub tracer: Arc<Tracer>,
    /// Shard pool to fan `trace <id>` collection out to (coordinator
    /// role only).
    pub pool: Option<Arc<ShardPool>>,
    /// SLO engine (`ckrig serve --slo`): evaluated on `health`/`stats`/
    /// `metricsx`, with `ok|warn|breach` statuses appended to those
    /// replies and state transitions logged once as structured warns.
    pub slo: Option<Arc<SloEngine>>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            metrics: Arc::new(ServerMetrics::new()),
            wal: None,
            health: Health::new(),
            tracer: Arc::new(Tracer::disabled()),
            pool: None,
            slo: None,
        }
    }
}

/// A running prediction server.
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<ServerMetrics>,
    registry: Arc<ModelRegistry>,
    health: Arc<Health>,
    tracer: Arc<Tracer>,
}

impl Server {
    /// Bind and serve a model registry in background threads (one per
    /// connection).
    pub fn start(registry: Arc<ModelRegistry>, cfg: ServerConfig) -> Result<Self> {
        Self::start_with_metrics(registry, cfg, Arc::new(ServerMetrics::new()))
    }

    /// [`Self::start`] against caller-owned metrics — so an embedding
    /// process can share one [`ServerMetrics`] between the server and
    /// other recorders (the shard coordinator wires its
    /// [`crate::coordinator::ShardPool`]'s degraded counter this way).
    pub fn start_with_metrics(
        registry: Arc<ModelRegistry>,
        cfg: ServerConfig,
        metrics: Arc<ServerMetrics>,
    ) -> Result<Self> {
        Self::start_with_options(registry, cfg, ServeOptions { metrics, ..Default::default() })
    }

    /// The full-control start: caller-owned metrics plus the durability
    /// and health wiring (`ckrig serve --wal` boots through this).
    pub fn start_with_options(
        registry: Arc<ModelRegistry>,
        cfg: ServerConfig,
        opts: ServeOptions,
    ) -> Result<Self> {
        let ServeOptions { metrics, wal, health, tracer, pool, slo } = opts;
        let batcher = Arc::new(Batcher::start_with_wal(
            registry.clone(),
            cfg.batcher.clone(),
            metrics.clone(),
            wal,
        ));
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));

        let accept_stop = stop.clone();
        let accept_metrics = metrics.clone();
        let accept_registry = registry.clone();
        let accept_health = health.clone();
        let accept_tracer = tracer.clone();
        let accept_thread = std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !accept_stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = faults::hit("accept-delay");
                        let b = batcher.clone();
                        let m = accept_metrics.clone();
                        let r = accept_registry.clone();
                        let s = accept_stop.clone();
                        let h = accept_health.clone();
                        let t = accept_tracer.clone();
                        let sp = pool.clone();
                        let sl = slo.clone();
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_connection(stream, b, r, m, s, h, t, sp, sl);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
                // Reap finished connection threads as we go — a
                // long-running server otherwise accumulates one dead
                // JoinHandle per client that ever connected.
                let mut i = 0;
                while i < conns.len() {
                    if conns[i].is_finished() {
                        let _ = conns.swap_remove(i).join();
                    } else {
                        i += 1;
                    }
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });

        Ok(Self {
            local_addr,
            stop,
            accept_thread: Some(accept_thread),
            metrics,
            registry,
            health,
            tracer,
        })
    }

    /// Convenience: serve a single model in a one-slot registry named
    /// `"default"`.
    pub fn start_with_model(model: Arc<dyn Surrogate>, cfg: ServerConfig) -> Result<Self> {
        Self::start(Arc::new(ModelRegistry::new("default", model)), cfg)
    }

    /// The registry this server resolves models from (for out-of-band
    /// loads/swaps by the embedding process).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// The health state this server's `health` op reports.
    pub fn health(&self) -> &Arc<Health> {
        &self.health
    }

    /// The span recorder this server's `trace` op reads from.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// Stop accepting and join every connection thread. In-flight
    /// requests complete (each connection finishes its current
    /// dispatch before noticing the stop flag), and dropping the
    /// batcher afterwards drains whatever its flush queue still holds —
    /// so shutdown doubles as the graceful drain.
    pub fn shutdown(&mut self) {
        self.health.draining.store(true, Ordering::Relaxed);
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Sentinel reply: close the connection without answering (used by the
/// fault-injection `spredict-drop` point to simulate a vanished shard).
const DROP_REPLY: &str = "\u{0}drop";

fn handle_connection(
    stream: TcpStream,
    batcher: Arc<Batcher>,
    registry: Arc<ModelRegistry>,
    metrics: Arc<ServerMetrics>,
    stop: Arc<AtomicBool>,
    health: Arc<Health>,
    tracer: Arc<Tracer>,
    pool: Option<Arc<ShardPool>>,
    slo: Option<Arc<SloEngine>>,
) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    // Line-sized writes + request/response ping-pong: Nagle + delayed ACK
    // would add ~40 ms per round trip (§Perf iteration 5).
    stream.set_nodelay(true)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                // Injected `delay` actions stall here (read/write
                // stalls); an injected `err` severs the connection the
                // way a dying peer would.
                if faults::hit("conn-read").is_err() {
                    return Ok(());
                }
                // One poisoned request must not take down the connection
                // thread (or the process): contain the panic, count it,
                // and answer with a protocol error.
                let reply = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    dispatch(
                        line.trim(),
                        &batcher,
                        &registry,
                        &metrics,
                        &health,
                        &tracer,
                        pool.as_deref(),
                        slo.as_deref(),
                    )
                }))
                .unwrap_or_else(|_| {
                    metrics.record_panic();
                    metrics.record_error();
                    "err internal: request handler panicked".to_string()
                });
                if reply == DROP_REPLY {
                    return Ok(());
                }
                if faults::hit("conn-write").is_err() {
                    return Ok(());
                }
                writer.write_all(reply.as_bytes())?;
                writer.write_all(b"\n")?;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => return Ok(()),
        }
    }
}

fn parse_csv_point(s: &str) -> Result<Vec<f64>> {
    s.split(',')
        .map(|f| f.trim().parse::<f64>().with_context(|| format!("bad number {f:?}")))
        .collect()
}

fn fmt_pair((mean, var): (f64, f64)) -> String {
    format!("{mean},{var}")
}

/// Parse and execute one protocol line.
fn dispatch(
    line: &str,
    batcher: &Batcher,
    registry: &ModelRegistry,
    metrics: &ServerMetrics,
    health: &Health,
    tracer: &Arc<Tracer>,
    pool: Option<&ShardPool>,
    slo: Option<&SloEngine>,
) -> String {
    metrics.record_request();
    let err = |msg: String| {
        metrics.record_error();
        format!("err {msg}")
    };
    if line == "ping" {
        return "ok pong".into();
    }
    if line == "metricsx" {
        return metricsx_for(batcher, registry, metrics, health, slo);
    }
    if line == "traces" {
        let ids: Vec<String> =
            tracer.recent_traces(16).into_iter().map(|id| format!("{id:x}")).collect();
        return format!("ok traces {}", ids.join(","));
    }
    if let Some(rest) = line.strip_prefix("trace ") {
        let id = match u64::from_str_radix(rest.trim(), 16) {
            Ok(v) if v != 0 => v,
            _ => return err(format!("bad trace id {:?}", rest.trim())),
        };
        let mut spans: Vec<WireSpan> = tracer
            .spans_for(id)
            .into_iter()
            .map(|span| WireSpan { proc: "local".into(), span })
            .collect();
        // Coordinator role: the same trace ID was propagated to shard
        // workers (`spredict … trace=`), so stitch their spans in,
        // relabeled `shard-<i>` by the pool.
        if let Some(pool) = pool {
            spans.extend(pool.collect_trace(id));
        }
        return format!("ok trace {id:x} {} {}", spans.len(), trace::encode_wire(&spans));
    }
    if line == "health" {
        let mut s = format!(
            "ok health ready={} draining={} depth={} panics={}",
            health.ready(),
            health.draining.load(Ordering::Relaxed),
            batcher.depth(),
            metrics.panics.load(Ordering::Relaxed),
        );
        if health.wal_attached.load(Ordering::Relaxed) {
            s.push_str(&format!(
                " wal_seq={} wal_unsynced={}",
                health.wal_last_seq.load(Ordering::Relaxed),
                health.wal_unsynced.load(Ordering::Relaxed),
            ));
        }
        let total = health.shards_total.load(Ordering::Relaxed);
        if total > 0 {
            s.push_str(&format!(
                " shards_alive={}/{total}",
                total.saturating_sub(health.shards_down.load(Ordering::Relaxed)),
            ));
        }
        // Aggregate memory posture of the online slots — the number an
        // orchestrator watches to confirm eviction policies are holding.
        let (points, bytes, fitting) = registry
            .list()
            .into_iter()
            .filter_map(|m| registry.get(Some(&m.name)))
            .filter_map(|model| model.observer().map(|o| o.online_stats()))
            .fold((0usize, 0usize, 0usize), |(p, b, f), os| {
                (
                    p + os.train_points,
                    b + os.resident_bytes,
                    f + os.refit_in_flight as usize,
                )
            });
        s.push_str(&format!(
            " model_points={points} model_bytes={bytes} refits_in_flight={fitting}"
        ));
        s.push_str(&format!(
            " uptime_s={:.0} started_unix={} version={}",
            metrics.uptime_s(),
            metrics.started_unix(),
            ServerMetrics::version(),
        ));
        if let Some(engine) = slo {
            let report = evaluate_slo(engine, registry, metrics);
            s.push_str(&format!(" slo={}", report.worst()));
        }
        return s;
    }
    if line == "stats" {
        let mut slots = Vec::new();
        let mut online = Vec::new();
        for m in registry.list() {
            if let Some(os) = registry
                .get(Some(&m.name))
                .and_then(|model| model.observer().map(|o| o.online_stats()))
            {
                // Refit posture per slot: idle, or fitting-for-µs, plus
                // the last completed refit's wall time once one ran.
                let refit_state = if os.refit_in_flight {
                    format!("fitting:{}us", os.refit_running_us)
                } else {
                    "idle".to_string()
                };
                online.push(format!(
                    "{}[points={} history={} bytes={} evicted={} refit={} last_refit={}us]",
                    m.name,
                    os.train_points,
                    os.history_len,
                    os.resident_bytes,
                    os.evicted,
                    refit_state,
                    os.last_refit_duration_us,
                ));
            }
            slots.push(m.name);
        }
        let mut s = format!(
            "ok {} slots={} default={}",
            metrics.summary(),
            slots.join(","),
            registry.default_name()
        );
        if !online.is_empty() {
            s.push_str(&format!(" online={}", online.join(",")));
        }
        s.push_str(&format!(
            " uptime_s={:.0} started_unix={} version={}",
            metrics.uptime_s(),
            metrics.started_unix(),
            ServerMetrics::version(),
        ));
        if let Some(engine) = slo {
            let report = evaluate_slo(engine, registry, metrics);
            s.push_str(&format!(" slo={}", report.worst()));
            if !report.models.is_empty() {
                let per_model: Vec<String> = report
                    .models
                    .iter()
                    .map(|(name, status)| format!("{name}:{status}"))
                    .collect();
                s.push_str(&format!(" slo_models={}", per_model.join(",")));
            }
        }
        // Coordinator role: aggregate each shard worker's numerical-health
        // token so one `stats` answers for the whole fleet.
        if let Some(pool) = pool {
            let shealth = pool.collect_health();
            if !shealth.is_empty() {
                let rows: Vec<String> =
                    shealth.iter().map(|(i, tok)| format!("{i}:{tok}")).collect();
                s.push_str(&format!(" shealth={}", rows.join("|")));
            }
        }
        return s;
    }
    if line == "models" {
        let rows: Vec<String> = registry
            .list()
            .into_iter()
            .map(|m| format!("{}:{}:d{}", m.name, m.algo, m.dim))
            .collect();
        return format!("ok default={} {}", registry.default_name(), rows.join(" "));
    }
    if let Some(rest) = line.strip_prefix("swap ") {
        let name = rest.trim();
        return match registry.set_default(name) {
            Ok(()) => format!("ok swapped {name}"),
            Err(e) => err(format!("{e:#}")),
        };
    }
    if let Some(rest) = line.strip_prefix("load ") {
        let mut parts = rest.split_whitespace();
        let path = match parts.next() {
            Some(p) => p,
            None => return err("load needs a path".into()),
        };
        let name = parts.next().map(str::to_string).unwrap_or_else(|| {
            std::path::Path::new(path)
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "default".into())
        });
        return match SurrogateSpec::load_path(path) {
            Ok(model) => {
                // Online-capable artifacts go behind the serving adapter
                // so the new slot accepts observe/observeb right away
                // (incremental only — runtime loads carry no refit spec).
                let model: Arc<dyn Surrogate> = match crate::online::OnlineModel::try_new(
                    model,
                    crate::online::OnlinePolicy::default(),
                ) {
                    Ok(adapter) => Arc::new(adapter),
                    Err(inner) => Arc::from(inner),
                };
                let (algo, dim) = (model.name().to_string(), model.dim());
                registry.insert(name.clone(), model);
                format!("ok loaded {name} {algo} d={dim}")
            }
            Err(e) => err(format!("{e:#}")),
        };
    }
    if let Some(rest) = line.strip_prefix("predict ") {
        // `predict <csv>` (v1) or `predict <model> <csv>` (v2). The first
        // token is a slot name when it names an existing slot (so numeric
        // slot names like "2024" stay addressable), or otherwise when it
        // can't be CSV data — which keeps v1 lines with spaces after
        // commas ("predict 1, 2") valid.
        let (model, csv) = match rest.trim().split_once(' ') {
            Some((m, c))
                if registry.contains(m.trim())
                    || (!m.contains(',') && m.parse::<f64>().is_err()) =>
            {
                (Some(m.trim()), c.trim())
            }
            _ => (None, rest.trim()),
        };
        return match parse_csv_point(csv) {
            Ok(point) => match batcher.predict_one_for(model, &point) {
                Ok(pair) => format!("ok {}", fmt_pair(pair)),
                Err(e) => err(format!("{e:#}")),
            },
            Err(e) => err(format!("{e:#}")),
        };
    }
    if let Some(rest) = line.strip_prefix("predictb ") {
        // `predictb [model] <n> <p1;p2;…> [trace=<hex>]`. A trailing
        // `trace=` token forces a trace under the client's ID (protocol
        // v7); without it the tracer's sampler decides.
        let mut tokens: Vec<&str> = rest.split_whitespace().collect();
        let forced = match tokens.last() {
            Some(t) if t.starts_with("trace=") => {
                let t = tokens.pop().unwrap();
                match u64::from_str_radix(&t["trace=".len()..], 16) {
                    Ok(v) if v != 0 => Some(v),
                    _ => return err(format!("bad trace id {t:?}")),
                }
            }
            _ => None,
        };
        let (model, n_str, body) = match tokens.as_slice() {
            [n, body] => (None, *n, *body),
            [model, n, body] => (Some(*model), *n, *body),
            _ => return err("usage: predictb [model] <n> <p1;p2;...>".into()),
        };
        let n: usize = match n_str.parse() {
            Ok(v) => v,
            Err(_) => return err(format!("bad point count {n_str:?}")),
        };
        let mut data = Vec::new();
        let mut rows = 0;
        let mut dim = None;
        for part in body.split(';') {
            let point = match parse_csv_point(part) {
                Ok(p) => p,
                Err(e) => return err(format!("point {}: {e:#}", rows + 1)),
            };
            if let Some(d) = dim {
                if point.len() != d {
                    return err(format!(
                        "point {} has {} dims, expected {d}",
                        rows + 1,
                        point.len()
                    ));
                }
            } else {
                dim = Some(point.len());
            }
            data.extend_from_slice(&point);
            rows += 1;
        }
        if rows != n {
            return err(format!("declared {n} points but got {rows}"));
        }
        // Mint the root span before enqueueing so the flush worker's
        // queue-wait/batch spans parent under it; record it after the
        // reply so its duration covers the full enqueue-to-answer time.
        let root = forced
            .or_else(|| tracer.sample())
            .map(|trace_id| (trace_id, tracer.next_id(), tracer.now_us()));
        let ctx = root.map(|(trace_id, root_id, _)| TraceCtx {
            tracer: Arc::clone(tracer),
            trace_id,
            parent: root_id,
        });
        let reply = match batcher.predict_rows_traced(model, data, rows, ctx) {
            Ok(pairs) => {
                let body: Vec<String> = pairs.into_iter().map(fmt_pair).collect();
                format!("ok {}", body.join(";"))
            }
            Err(e) => err(format!("{e:#}")),
        };
        if let Some((trace_id, root_id, start_us)) = root {
            tracer.record(Span {
                trace_id,
                span_id: root_id,
                parent_id: 0,
                name: "predictb".into(),
                start_us,
                dur_us: tracer.now_us().saturating_sub(start_us),
            });
        }
        return reply;
    }
    if let Some(rest) = line.strip_prefix("spredict ") {
        // `spredict [model] <n> <p1;p2;…> [clusters=c1,c2,…]` — raw
        // per-cluster posteriors for a scatter-gather coordinator. Served
        // directly (not through the Batcher): the coordinator's batcher
        // already formed this batch, and re-queueing it would serialize
        // independent shards behind one flush worker.
        let mut tokens: Vec<&str> = rest.split_whitespace().collect();
        // An optional `trace=` token rides after `clusters=` (protocol
        // v7): the coordinator propagating its trace ID into this shard.
        let forced = match tokens.last() {
            Some(t) if t.starts_with("trace=") => {
                let t = tokens.pop().unwrap();
                match u64::from_str_radix(&t["trace=".len()..], 16) {
                    Ok(v) if v != 0 => Some(v),
                    _ => return err(format!("bad trace id {t:?}")),
                }
            }
            _ => None,
        };
        let has_filter = tokens.last().is_some_and(|t| t.starts_with("clusters="));
        let filter: Option<Vec<usize>> = if has_filter {
            let t = tokens.pop().unwrap();
            let parsed: std::result::Result<Vec<usize>, _> =
                t["clusters=".len()..].split(',').map(|c| c.trim().parse()).collect();
            match parsed {
                Ok(v) if !v.is_empty() => Some(v),
                _ => return err(format!("bad cluster filter {t:?}")),
            }
        } else {
            None
        };
        let (model, n_str, body) = match tokens.as_slice() {
            [n, body] => (None, *n, *body),
            [model, n, body] => (Some(*model), *n, *body),
            _ => {
                return err(
                    "usage: spredict [model] <n> <p1;p2;...> [clusters=c1,c2,...]".into(),
                )
            }
        };
        let n: usize = match n_str.parse() {
            Ok(v) => v,
            Err(_) => return err(format!("bad point count {n_str:?}")),
        };
        let mut data = Vec::new();
        let mut rows = 0;
        let mut dim = None;
        for part in body.split(';') {
            let point = match parse_csv_point(part) {
                Ok(p) => p,
                Err(e) => return err(format!("point {}: {e:#}", rows + 1)),
            };
            if let Some(d) = dim {
                if point.len() != d {
                    return err(format!(
                        "point {} has {} dims, expected {d}",
                        rows + 1,
                        point.len()
                    ));
                }
            } else {
                dim = Some(point.len());
            }
            data.extend_from_slice(&point);
            rows += 1;
        }
        if rows != n {
            return err(format!("declared {n} points but got {rows}"));
        }
        // Chaos hooks for the distributed path: `spredict` stalls/errors
        // here; `spredict-drop` severs the connection without a reply.
        if faults::hit("spredict-drop").is_err() {
            return DROP_REPLY.into();
        }
        if let Err(e) = faults::hit("spredict") {
            return err(format!("{e:#}"));
        }
        // A forced trace wraps execution in an `spredict` root span;
        // model internals (kernel assembly, solves) nest under it via
        // the ambient context.
        let root = forced.map(|trace_id| (trace_id, tracer.next_id(), tracer.now_us()));
        let result = {
            let _guard = root.map(|(trace_id, root_id, _)| {
                trace::enter(TraceCtx {
                    tracer: Arc::clone(tracer),
                    trace_id,
                    parent: root_id,
                })
            });
            spredict_for(model, data, rows, filter.as_deref(), registry, metrics)
        };
        if let Some((trace_id, root_id, start_us)) = root {
            tracer.record(Span {
                trace_id,
                span_id: root_id,
                parent_id: 0,
                name: "spredict".into(),
                start_us,
                dur_us: tracer.now_us().saturating_sub(start_us),
            });
        }
        return match result {
            Ok(reply) => format!("ok {reply}"),
            Err(e) => err(format!("{e:#}")),
        };
    }
    if line == "shardinfo" || line.starts_with("shardinfo ") {
        let model = line.strip_prefix("shardinfo").unwrap().trim();
        let model = if model.is_empty() { None } else { Some(model) };
        return match shardinfo_for(model, registry) {
            Ok(reply) => format!("ok {reply}"),
            Err(e) => err(format!("{e:#}")),
        };
    }
    if let Some(rest) = line.strip_prefix("suggest ") {
        // `suggest [model] <q> [bounds]`. First token is a slot name when
        // it names an existing slot or cannot be a point count.
        let tokens: Vec<&str> = rest.split_whitespace().collect();
        let (model, q_str, bounds_str) = match tokens.as_slice() {
            [q] => (None, *q, None),
            [a, b] => {
                if registry.contains(a) || a.parse::<usize>().is_err() {
                    (Some(*a), *b, None)
                } else {
                    (None, *a, Some(*b))
                }
            }
            [m, q, b] => (Some(*m), *q, Some(*b)),
            _ => return err("usage: suggest [model] <q> [lo1,hi1;lo2,hi2;...]".into()),
        };
        let q: usize = match q_str.parse() {
            Ok(v) if v >= 1 => v,
            _ => return err(format!("bad proposal count {q_str:?}")),
        };
        let t0 = std::time::Instant::now();
        return match suggest_for(model, q, bounds_str, registry, metrics) {
            Ok(points) => {
                metrics.record_op(ProtocolOp::Suggest, t0.elapsed().as_secs_f64());
                format!("ok {points}")
            }
            Err(e) => err(format!("{e:#}")),
        };
    }
    if let Some(rest) = line.strip_prefix("tell ") {
        // `tell [model] <csv>` — an evaluated suggestion coming back:
        // the point's features followed by the objective value. Identical
        // shape to `observe` and rides the same flush queue, so the next
        // flush's predictions (and suggestions) see the updated
        // posterior.
        let (model, csv) = match rest.trim().split_once(' ') {
            Some((m, c))
                if registry.contains(m.trim())
                    || (!m.contains(',') && m.parse::<f64>().is_err()) =>
            {
                (Some(m.trim()), c.trim())
            }
            _ => (None, rest.trim()),
        };
        return match parse_csv_point(csv) {
            Ok(row) if row.len() >= 2 => match batcher.observe_rows(model, row, 1) {
                Ok(()) => "ok told 1".into(),
                Err(e) => err(format!("{e:#}")),
            },
            Ok(_) => err("tell needs at least one feature and the objective value".into()),
            Err(e) => err(format!("{e:#}")),
        };
    }
    if let Some(rest) = line.strip_prefix("observe ") {
        // `observe [model] <csv>` where the CSV carries the point's
        // features followed by the target value. Model-name detection
        // mirrors `predict`.
        let (model, csv) = match rest.trim().split_once(' ') {
            Some((m, c))
                if registry.contains(m.trim())
                    || (!m.contains(',') && m.parse::<f64>().is_err()) =>
            {
                (Some(m.trim()), c.trim())
            }
            _ => (None, rest.trim()),
        };
        return match parse_csv_point(csv) {
            Ok(row) if row.len() >= 2 => match batcher.observe_rows(model, row, 1) {
                Ok(()) => "ok observed 1".into(),
                Err(e) => err(format!("{e:#}")),
            },
            Ok(_) => err("observe needs at least one feature and a target".into()),
            Err(e) => err(format!("{e:#}")),
        };
    }
    if let Some(rest) = line.strip_prefix("observeb ") {
        // `observeb [model] <n> <o1;o2;…>`, each `oi` a d+1-value CSV.
        let tokens: Vec<&str> = rest.split_whitespace().collect();
        let (model, n_str, body) = match tokens.as_slice() {
            [n, body] => (None, *n, *body),
            [model, n, body] => (Some(*model), *n, *body),
            _ => return err("usage: observeb [model] <n> <o1;o2;...>".into()),
        };
        let n: usize = match n_str.parse() {
            Ok(v) => v,
            Err(_) => return err(format!("bad observation count {n_str:?}")),
        };
        let mut data = Vec::new();
        let mut rows = 0;
        let mut width = None;
        for part in body.split(';') {
            let row = match parse_csv_point(part) {
                Ok(p) => p,
                Err(e) => return err(format!("observation {}: {e:#}", rows + 1)),
            };
            if let Some(w) = width {
                if row.len() != w {
                    return err(format!(
                        "observation {} has {} values, expected {w}",
                        rows + 1,
                        row.len()
                    ));
                }
            } else {
                if row.len() < 2 {
                    return err("each observation needs features and a target".into());
                }
                width = Some(row.len());
            }
            data.extend_from_slice(&row);
            rows += 1;
        }
        if rows != n {
            return err(format!("declared {n} observations but got {rows}"));
        }
        return match batcher.observe_rows(model, data, rows) {
            Ok(()) => format!("ok observed {rows}"),
            Err(e) => err(format!("{e:#}")),
        };
    }
    err(format!("unknown command {line:?}"))
}

/// Feed the SLO engine one evaluation round from the live counters and
/// quality monitors, logging each state transition exactly once as a
/// structured warn (the engine owns transition dedup, so concurrent
/// `health`/`stats`/`metricsx` requests cannot double-log).
fn evaluate_slo(
    engine: &SloEngine,
    registry: &ModelRegistry,
    metrics: &ServerMetrics,
) -> SloReport {
    let models: Vec<(String, bool)> = registry
        .list()
        .into_iter()
        .map(|m| {
            let miscalibrated = registry
                .get(Some(&m.name))
                .and_then(|model| {
                    model.observer().map(|o| o.online_stats().quality.flagged())
                })
                .unwrap_or(false);
            (m.name, miscalibrated)
        })
        .collect();
    let report = engine.evaluate(&SloInputs {
        predict: metrics.op_snapshot(ProtocolOp::Predict),
        requests: metrics.requests.load(Ordering::Relaxed),
        errors: metrics.errors.load(Ordering::Relaxed),
        models,
    });
    for (model, from, to) in &report.transitions {
        log::warn!(
            "SLO transition: model={model} {from}->{to} (spec {}, p99={}us err_rate={:.6})",
            engine.spec(),
            report.p99_us,
            report.err_rate,
        );
    }
    report
}

/// Assemble the `metricsx` exposition document: everything `stats`
/// reports, as Prometheus-style text, plus WAL lag, shard liveness,
/// latency bucket histograms, numerical-health counters and the
/// per-model prequential quality gauges. Lives here because the server
/// is the one place that sees the metrics, the health gauges and the
/// model registry at once.
fn metricsx_for(
    batcher: &Batcher,
    registry: &ModelRegistry,
    metrics: &ServerMetrics,
    health: &Health,
    slo: Option<&SloEngine>,
) -> String {
    fn model_rows<'a>(
        online: &'a [(String, crate::online::OnlineStats)],
        f: impl Fn(&crate::online::OnlineStats) -> f64,
    ) -> Vec<(Vec<(&'a str, &'a str)>, f64)> {
        online.iter().map(|(name, os)| (vec![("model", name.as_str())], f(os))).collect()
    }
    let c = |a: &AtomicU64| a.load(Ordering::Relaxed);

    let mut p = PromText::new();
    p.gauge("ckrig_uptime_seconds", "Seconds since this server booted.", metrics.uptime_s());
    p.gauge(
        "ckrig_started_unix",
        "Boot wall-clock time (Unix seconds).",
        metrics.started_unix() as f64,
    );
    p.gauge_family(
        "ckrig_build_info",
        "Build identity (constant 1; version in the label).",
        &[(vec![("version", ServerMetrics::version())], 1.0)],
    );
    p.counter("ckrig_requests_total", "Protocol requests received.", c(&metrics.requests));
    p.counter("ckrig_predictions_total", "Prediction rows served.", c(&metrics.predictions));
    p.counter("ckrig_observes_total", "Observations absorbed.", c(&metrics.observes));
    p.counter("ckrig_suggests_total", "Candidate points proposed.", c(&metrics.suggests));
    p.counter(
        "ckrig_spredicts_total",
        "Raw per-cluster rows served as a shard worker.",
        c(&metrics.spredicts),
    );
    p.counter(
        "ckrig_degraded_total",
        "Scatter-gather merges that dropped at least one shard.",
        c(&metrics.degraded),
    );
    p.counter("ckrig_retries_total", "Shard sub-requests retried.", c(&metrics.retries));
    p.counter("ckrig_panics_total", "Contained request-handler panics.", c(&metrics.panics));
    p.counter("ckrig_batches_total", "Prediction flushes executed.", c(&metrics.batches));
    p.counter("ckrig_errors_total", "Protocol errors answered.", c(&metrics.errors));
    p.gauge(
        "ckrig_ready",
        "1 when this process should receive traffic.",
        health.ready() as u64 as f64,
    );
    p.gauge(
        "ckrig_draining",
        "1 while a graceful drain is in progress.",
        health.draining.load(Ordering::Relaxed) as u64 as f64,
    );
    p.gauge("ckrig_queue_depth_points", "Flush-queue backlog in points.", batcher.depth() as f64);
    if health.wal_attached.load(Ordering::Relaxed) {
        p.gauge(
            "ckrig_wal_last_seq",
            "Last write-ahead-log sequence number appended.",
            c(&health.wal_last_seq) as f64,
        );
        p.gauge(
            "ckrig_wal_unsynced",
            "Appended-but-unsynced WAL records (durability lag).",
            c(&health.wal_unsynced) as f64,
        );
    }
    let shards_total = c(&health.shards_total);
    if shards_total > 0 {
        p.gauge("ckrig_shards_total", "Shard workers in the fan-out pool.", shards_total as f64);
        p.gauge(
            "ckrig_shards_alive",
            "Shard workers currently serving.",
            shards_total.saturating_sub(c(&health.shards_down)) as f64,
        );
    }
    p.histogram_family(
        "ckrig_request_latency_us",
        "Aggregate op execution latency (µs buckets).",
        &[(vec![], metrics.latency_snapshot())],
    );
    let op_rows: Vec<_> = ProtocolOp::ALL
        .iter()
        .filter(|op| metrics.op_count(**op) > 0)
        .map(|op| (vec![("op", op.key())], metrics.op_snapshot(*op)))
        .collect();
    p.histogram_family("ckrig_op_latency_us", "Per-op execution latency (µs buckets).", &op_rows);

    // Per-model gauges: memory/refit posture plus prequential quality,
    // one labeled sample per online slot.
    let online: Vec<(String, crate::online::OnlineStats)> = registry
        .list()
        .into_iter()
        .filter_map(|m| {
            registry
                .get(Some(&m.name))
                .and_then(|model| model.observer().map(|o| (m.name, o.online_stats())))
        })
        .collect();
    p.gauge_family(
        "ckrig_model_train_points",
        "Training points currently held by the live model.",
        &model_rows(&online, |os| os.train_points as f64),
    );
    p.gauge_family(
        "ckrig_model_resident_bytes",
        "Approximate resident bytes of fitted state.",
        &model_rows(&online, |os| os.resident_bytes as f64),
    );
    p.gauge_family(
        "ckrig_model_history_len",
        "Raw-unit refit-history length.",
        &model_rows(&online, |os| os.history_len as f64),
    );
    p.gauge_family(
        "ckrig_model_evicted_total",
        "Training points evicted over the adapter's lifetime.",
        &model_rows(&online, |os| os.evicted as f64),
    );
    p.gauge_family(
        "ckrig_model_refits_total",
        "Background refits hot-swapped in over the adapter's lifetime.",
        &model_rows(&online, |os| os.refits as f64),
    );
    p.gauge_family(
        "ckrig_model_refit_in_flight",
        "1 while a background refit is running for the slot.",
        &model_rows(&online, |os| os.refit_in_flight as u64 as f64),
    );
    p.gauge_family(
        "ckrig_model_refit_running_us",
        "Wall µs the in-flight background refit has been running (0 idle).",
        &model_rows(&online, |os| os.refit_running_us as f64),
    );
    p.gauge_family(
        "ckrig_model_last_refit_duration_us",
        "Wall µs of the last completed background refit attempt.",
        &model_rows(&online, |os| os.last_refit_duration_us as f64),
    );
    p.gauge_family(
        "ckrig_model_observed_total",
        "Observations absorbed over the adapter's lifetime.",
        &model_rows(&online, |os| os.observed as f64),
    );
    p.gauge_family(
        "ckrig_model_drift",
        "Rolling mean standardized residual (the refit trigger).",
        &model_rows(&online, |os| os.drift),
    );
    p.gauge_family(
        "ckrig_model_quality_scored_total",
        "Observations prequentially scored against the pre-update posterior.",
        &model_rows(&online, |os| os.quality.scored as f64),
    );
    p.gauge_family(
        "ckrig_model_mean_z2",
        "Rolling mean squared standardized residual (1 = calibrated).",
        &model_rows(&online, |os| os.quality.mean_z2),
    );
    p.gauge_family(
        "ckrig_model_coverage90",
        "Empirical 90% interval coverage (nominal 0.90).",
        &model_rows(&online, |os| os.quality.coverage90),
    );
    p.gauge_family(
        "ckrig_model_coverage95",
        "Empirical 95% interval coverage (nominal 0.95).",
        &model_rows(&online, |os| os.quality.coverage95),
    );
    p.gauge_family(
        "ckrig_model_coverage99",
        "Empirical 99% interval coverage (nominal 0.99).",
        &model_rows(&online, |os| os.quality.coverage99),
    );
    p.gauge_family(
        "ckrig_model_quality_rmse",
        "Windowed prequential prediction RMSE (raw units).",
        &model_rows(&online, |os| os.quality.rmse),
    );
    p.gauge_family(
        "ckrig_model_calibration_flagged",
        "1 when empirical interval coverage deviates beyond tolerance.",
        &model_rows(&online, |os| os.quality.flagged() as u64 as f64),
    );

    // Process-wide degeneracy counters: cheap always-on tallies of the
    // numerical escape hatches the math core had to take.
    let deg = crate::obs::health::counters().snapshot();
    p.counter(
        "ckrig_degeneracy_jitter_escalations_total",
        "Cholesky factorizations that needed diagonal jitter to go PD.",
        deg.jitter_escalations,
    );
    p.counter(
        "ckrig_degeneracy_factor_fallbacks_total",
        "Rank-one updates that fell back to a full refactorization.",
        deg.factor_fallbacks,
    );
    p.counter(
        "ckrig_degeneracy_combiner_floor_hits_total",
        "Ensemble combines where a member hit the variance floor.",
        deg.combiner_floor_hits,
    );
    p.counter(
        "ckrig_degeneracy_nonfinite_rejected_total",
        "Observations rejected for non-finite coordinates or values.",
        deg.nonfinite_rejected,
    );
    p.counter(
        "ckrig_degeneracy_nugget_boundary_hits_total",
        "Hyperparameter evaluations pinned at the nugget search boundary.",
        deg.nugget_boundary_hits,
    );
    p.gauge(
        "ckrig_degeneracy_last_jitter",
        "Jitter magnitude of the most recent escalated factorization.",
        deg.last_jitter,
    );
    p.gauge(
        "ckrig_degeneracy_max_jitter",
        "Largest jitter magnitude any factorization has needed.",
        deg.max_jitter,
    );

    // Per-model conditioning gauges, for slots whose model exposes a
    // health report. May lazily probe (O(n²) per cluster) — metricsx is
    // a scrape op, never the predict hot path.
    let reports: Vec<(String, crate::obs::health::HealthReport)> = registry
        .list()
        .into_iter()
        .filter_map(|m| {
            registry.get(Some(&m.name)).and_then(|model| {
                model.health_report().map(|r| (m.name, r))
            })
        })
        .collect();
    let health_rows = |f: &dyn Fn(&crate::obs::health::HealthReport) -> f64| {
        reports
            .iter()
            .map(|(name, r)| (vec![("model", name.as_str())], f(r)))
            .collect::<Vec<_>>()
    };
    p.gauge_family(
        "ckrig_model_cond_estimate",
        "Worst per-cluster 1-norm condition estimate of the fitted factors.",
        &health_rows(&|r| r.max_cond()),
    );
    p.gauge_family(
        "ckrig_model_jitter",
        "Largest diagonal jitter any of the model's factorizations needed.",
        &health_rows(&|r| r.max_jitter()),
    );
    p.gauge_family(
        "ckrig_model_health_class",
        "Worst conditioning class across clusters (0 ok, 1 warn, 2 critical).",
        &health_rows(&|r| r.worst_class().code() as f64),
    );

    // SLO statuses, when the server was started with a spec.
    if let Some(engine) = slo {
        let report = evaluate_slo(engine, registry, metrics);
        p.gauge(
            "ckrig_slo_worst",
            "Worst SLO status across dimensions and models (0 ok, 1 warn, 2 breach).",
            report.worst().code() as f64,
        );
        let slo_rows: Vec<(Vec<(&str, &str)>, f64)> = report
            .models
            .iter()
            .map(|(name, status)| (vec![("model", name.as_str())], status.code() as f64))
            .collect();
        p.gauge_family(
            "ckrig_slo_status",
            "Per-model SLO status (0 ok, 1 warn, 2 breach).",
            &slo_rows,
        );
    }
    p.finish()
}

/// Execute one `suggest` op: propose `q` points that maximize Expected
/// Improvement over the slot's posterior. The incumbent (and, when the
/// request carries no explicit box, the search bounds) come from the
/// slot's training snapshot, so the slot must be online-capable — which
/// every `serve`/`load` path wraps automatically when the model supports
/// it. The shared slot model is never mutated: batch spreading uses the
/// non-fantasizing greedy selection of [`crate::optimize::propose`].
fn suggest_for(
    model: Option<&str>,
    q: usize,
    bounds_str: Option<&str>,
    registry: &ModelRegistry,
    metrics: &ServerMetrics,
) -> Result<String> {
    let target = registry
        .get(model)
        .ok_or_else(|| anyhow::anyhow!("no model slot named {:?}", model.unwrap_or("")))?;
    let (xs, ys) = target
        .observer()
        .and_then(|o| o.training_snapshot())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "model slot {:?} has no training snapshot (not online-capable); \
                 suggest needs the incumbent",
                model.unwrap_or("default")
            )
        })?;
    anyhow::ensure!(!ys.is_empty(), "slot has an empty training history");
    let bounds = match bounds_str {
        Some(s) => crate::optimize::Bounds::parse(s).context("parsing suggest bounds")?,
        None => crate::optimize::Bounds::from_data(&xs, 0.05)?,
    };
    let inc = crate::util::stats::argmin(&ys);
    let best = ys[inc];
    // Deterministic per-request stream: seeded off the running suggests
    // counter, so repeated identical requests still explore fresh pools
    // while a replayed session reproduces exactly.
    let seed =
        0x5EED_C0DE_u64 ^ metrics.suggests.load(std::sync::atomic::Ordering::Relaxed);
    let mut rng = crate::util::rng::Rng::new(seed);
    let points = crate::optimize::propose(
        target.as_ref(),
        &bounds,
        best,
        Some(xs.row(inc)),
        q,
        crate::optimize::Acquisition::ei(),
        512,
        &mut rng,
    )?;
    metrics.record_suggests(q);
    let body: Vec<String> = (0..points.rows())
        .map(|i| points.row(i).iter().map(f64::to_string).collect::<Vec<_>>().join(","))
        .collect();
    Ok(body.join(";"))
}

/// Execute one `spredict` op: raw per-cluster posteriors from the slot's
/// [`crate::distributed::ShardPredictor`] view.
fn spredict_for(
    model: Option<&str>,
    data: Vec<f64>,
    rows: usize,
    filter: Option<&[usize]>,
    registry: &ModelRegistry,
    metrics: &ServerMetrics,
) -> Result<String> {
    let target = registry
        .get(model)
        .ok_or_else(|| anyhow::anyhow!("no model slot named {:?}", model.unwrap_or("")))?;
    let sp = target.shard_predictor().ok_or_else(|| {
        anyhow::anyhow!(
            "model slot {:?} has no per-cluster decomposition (spredict serves \
             Cluster Kriging ensembles and shards)",
            model.unwrap_or("default")
        )
    })?;
    let dim = target.dim();
    anyhow::ensure!(
        data.len() == rows * dim,
        "expected {rows}×{dim} values for model {:?}, got {}",
        model.unwrap_or("default"),
        data.len()
    );
    let xt = Matrix::from_vec(rows, dim, data);
    let t0 = std::time::Instant::now();
    let partials = sp.predict_clusters(&xt, filter)?;
    metrics.record_op(ProtocolOp::ShardPredict, t0.elapsed().as_secs_f64());
    metrics.record_spredicts(rows);
    let body: Vec<String> = partials
        .iter()
        .map(|row| {
            row.iter()
                .map(|(c, m, v)| format!("{c}:{m},{v}"))
                .collect::<Vec<_>>()
                .join("|")
        })
        .collect();
    Ok(format!("spreds {}", body.join(";")))
}

/// Execute one `shardinfo` op: the topology handshake a coordinator's
/// connection pool validates against its manifest.
fn shardinfo_for(model: Option<&str>, registry: &ModelRegistry) -> Result<String> {
    let target = registry
        .get(model)
        .ok_or_else(|| anyhow::anyhow!("no model slot named {:?}", model.unwrap_or("")))?;
    let sp = target.shard_predictor().ok_or_else(|| {
        anyhow::anyhow!(
            "model slot {:?} has no per-cluster decomposition",
            model.unwrap_or("default")
        )
    })?;
    let (index, count) = sp.shard_index().unwrap_or((0, 1));
    let clusters: Vec<String> = sp.cluster_ids().iter().map(usize::to_string).collect();
    let mut reply = format!(
        "shard {index}/{count} k={} d={} clusters={} algo={}",
        sp.k_total(),
        target.dim(),
        clusters.join(","),
        target.name()
    );
    // Numerical-health summary rides along so a coordinator can
    // aggregate fleet conditioning without a second round-trip.
    if let Some(report) = target.health_report() {
        reply.push_str(&format!(" shealth={}", report.wire_token()));
    }
    Ok(reply)
}

/// One shard worker's topology, as reported by `shardinfo` (see
/// [`Client::shard_info`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardInfo {
    pub index: usize,
    pub count: usize,
    pub k_total: usize,
    pub dim: usize,
    pub clusters: Vec<usize>,
    pub algo: String,
    /// Numerical-health wire token (`cond:…,jit:…,worst:…`), absent when
    /// the worker predates health reporting or its model exposes none.
    pub shealth: Option<String>,
}

/// Capped exponential backoff with full jitter for [`Client`] retries
/// of **idempotent** ops. Attempt `k` (1-based) sleeps a uniform random
/// duration in `[0, min(cap, base·2^(k-1))]` before reconnecting.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Extra attempts beyond the first (0 = retries disabled).
    pub max_retries: u32,
    pub base: Duration,
    pub cap: Duration,
    /// Seed for the deterministic jitter stream.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_retries: 2,
            base: Duration::from_millis(25),
            cap: Duration::from_secs(1),
            seed: 0x5EED_7E57,
        }
    }
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    addr: String,
    read_timeout: Option<Duration>,
    write_timeout: Option<Duration>,
    retry: Option<RetryPolicy>,
    jitter: Rng,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Self::from_stream(stream, addr)
    }

    /// [`Self::connect`] with a connection deadline, for callers that
    /// must not block on an unreachable server (the shard pool).
    pub fn connect_with_timeout(addr: &str, timeout: Duration) -> Result<Self> {
        use std::net::ToSocketAddrs;
        let sockaddr = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .next()
            .with_context(|| format!("{addr} resolves to no address"))?;
        let stream = TcpStream::connect_timeout(&sockaddr, timeout)
            .with_context(|| format!("connecting to {addr}"))?;
        Self::from_stream(stream, addr)
    }

    fn from_stream(stream: TcpStream, addr: &str) -> Result<Self> {
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(Self {
            reader: BufReader::new(stream),
            writer,
            addr: addr.to_string(),
            read_timeout: None,
            write_timeout: None,
            retry: None,
            jitter: Rng::new(0x5EED_7E57),
        })
    }

    /// Enable reconnect-and-retry for idempotent requests.
    pub fn with_retry(mut self, policy: RetryPolicy) -> Self {
        self.jitter = Rng::new(policy.seed);
        self.retry = Some(policy);
        self
    }

    /// Per-request socket deadlines. `None` restores the default
    /// block-forever behavior. With a read deadline set,
    /// [`Self::request`] returns an error instead of hanging when the
    /// server dies mid-response — after which this connection is poisoned
    /// (a late reply would desynchronize the request/reply pairing) and
    /// should be dropped and re-established (or left to the retry path,
    /// which reconnects before re-sending).
    pub fn set_timeouts(&mut self, read: Option<Duration>, write: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(read)?;
        self.writer.set_write_timeout(write)?;
        self.read_timeout = read;
        self.write_timeout = write;
        Ok(())
    }

    /// Replace a poisoned connection with a fresh one to the same
    /// address, re-applying the configured socket deadlines.
    fn reconnect(&mut self) -> Result<()> {
        let stream = TcpStream::connect(&self.addr)
            .with_context(|| format!("reconnecting to {}", self.addr))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(self.read_timeout)?;
        stream.set_write_timeout(self.write_timeout)?;
        self.writer = stream.try_clone()?;
        self.reader = BufReader::new(stream);
        Ok(())
    }

    /// One request with reconnect-and-retry, for **idempotent** ops only
    /// (`predictb`/`spredict`/`shardinfo`/…). Mutating ops (`observe`,
    /// `tell`) must never route through here: a timed-out mutation may
    /// already have been applied, and re-sending it would double-apply.
    /// Without a [`RetryPolicy`] this is plain [`Self::request`].
    fn request_idempotent(&mut self, line: &str) -> Result<String> {
        let Some(policy) = self.retry.clone() else {
            return self.request(line);
        };
        let mut last_err = None;
        for attempt in 0..=policy.max_retries {
            if attempt > 0 {
                let exp = policy.base.saturating_mul(1u32 << (attempt - 1).min(20));
                let cap = exp.min(policy.cap);
                // Full jitter: uniform in [0, cap] decorrelates clients
                // hammering a just-recovered server.
                let sleep = cap.mul_f64(self.jitter.uniform());
                std::thread::sleep(sleep);
                if let Err(e) = self.reconnect() {
                    last_err = Some(e);
                    continue;
                }
            }
            match self.request(line) {
                Ok(reply) => return Ok(reply),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow::anyhow!("retries exhausted"))).with_context(
            || format!("after {} attempts against {}", policy.max_retries + 1, self.addr),
        )
    }

    pub fn request(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).map_err(|e| {
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut
            {
                anyhow::anyhow!("request timed out waiting for a reply (connection poisoned)")
            } else {
                anyhow::Error::from(e)
            }
        })?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Ok(reply.trim().to_string())
    }

    fn expect_ok<'a>(reply: &'a str) -> Result<&'a str> {
        reply.strip_prefix("ok ").with_context(|| format!("server error: {reply}"))
    }

    /// Predict a batch of points through the `predictb` protocol path;
    /// `model` picks a registry slot (`None` = server default).
    pub fn predict_batch<P: AsRef<[f64]>>(
        &mut self,
        model: Option<&str>,
        points: &[P],
    ) -> Result<Vec<(f64, f64)>> {
        self.predict_batch_traced(model, points, None)
    }

    /// [`Self::predict_batch`] with a forced trace ID (protocol v7): the
    /// server records the request's span tree under `trace`, ready for a
    /// follow-up [`Self::trace_spans`] call.
    pub fn predict_batch_traced<P: AsRef<[f64]>>(
        &mut self,
        model: Option<&str>,
        points: &[P],
        trace: Option<u64>,
    ) -> Result<Vec<(f64, f64)>> {
        anyhow::ensure!(!points.is_empty(), "predict_batch needs at least one point");
        let body: Vec<String> = points
            .iter()
            .map(|p| {
                p.as_ref().iter().map(f64::to_string).collect::<Vec<_>>().join(",")
            })
            .collect();
        let prefix = match model {
            Some(m) => format!("predictb {m} "),
            None => "predictb ".to_string(),
        };
        let mut line = format!("{prefix}{} {}", points.len(), body.join(";"));
        if let Some(t) = trace {
            line.push_str(&format!(" trace={t:x}"));
        }
        let reply = self.request_idempotent(&line)?;
        let rest = Self::expect_ok(&reply)?;
        let mut out = Vec::with_capacity(points.len());
        for pair in rest.split(';') {
            let (m, v) = pair.split_once(',').context("malformed reply pair")?;
            out.push((m.parse()?, v.parse()?));
        }
        anyhow::ensure!(
            out.len() == points.len(),
            "server returned {} predictions for {} points",
            out.len(),
            points.len()
        );
        Ok(out)
    }

    /// Predict one point (rides the batch path, so every client predict
    /// exercises the v2 protocol).
    pub fn predict(&mut self, point: &[f64]) -> Result<(f64, f64)> {
        Ok(self.predict_batch(None, &[point])?[0])
    }

    /// Load a server-side artifact into a registry slot; returns the slot
    /// name the server chose.
    pub fn load_model(&mut self, path: &str, name: Option<&str>) -> Result<String> {
        let line = match name {
            Some(n) => format!("load {path} {n}"),
            None => format!("load {path}"),
        };
        let reply = self.request(&line)?;
        let rest = Self::expect_ok(&reply)?;
        let mut parts = rest.split_whitespace();
        anyhow::ensure!(parts.next() == Some("loaded"), "unexpected reply: {reply}");
        parts.next().map(str::to_string).context("reply missing slot name")
    }

    /// Retarget the server's default model slot.
    pub fn swap(&mut self, name: &str) -> Result<()> {
        let reply = self.request(&format!("swap {name}"))?;
        Self::expect_ok(&reply)?;
        Ok(())
    }

    /// Raw `models` listing.
    pub fn models(&mut self) -> Result<String> {
        let reply = self.request("models")?;
        Ok(Self::expect_ok(&reply)?.to_string())
    }

    /// Raw `stats` reply (metrics summary + slot names).
    pub fn stats(&mut self) -> Result<String> {
        let reply = self.request("stats")?;
        Ok(Self::expect_ok(&reply)?.to_string())
    }

    /// Stream a batch of observations through the `observeb` protocol
    /// path; `model` picks a registry slot (`None` = server default).
    /// Returns the number of observations the server absorbed.
    pub fn observe_batch<P: AsRef<[f64]>>(
        &mut self,
        model: Option<&str>,
        points: &[P],
        ys: &[f64],
    ) -> Result<usize> {
        anyhow::ensure!(!points.is_empty(), "observe_batch needs at least one observation");
        anyhow::ensure!(
            points.len() == ys.len(),
            "observe_batch: {} points but {} targets",
            points.len(),
            ys.len()
        );
        let body: Vec<String> = points
            .iter()
            .zip(ys)
            .map(|(p, y)| {
                let mut row: Vec<String> =
                    p.as_ref().iter().map(f64::to_string).collect();
                row.push(y.to_string());
                row.join(",")
            })
            .collect();
        let prefix = match model {
            Some(m) => format!("observeb {m} "),
            None => "observeb ".to_string(),
        };
        let reply =
            self.request(&format!("{prefix}{} {}", points.len(), body.join(";")))?;
        let rest = Self::expect_ok(&reply)?;
        let count = rest
            .strip_prefix("observed ")
            .with_context(|| format!("unexpected reply: {reply}"))?;
        Ok(count.trim().parse()?)
    }

    /// Stream one observation (rides the batch path).
    pub fn observe(&mut self, point: &[f64], y: f64) -> Result<()> {
        self.observe_batch(None, &[point], &[y]).map(|_| ())
    }

    /// Ask a served model for `q` points to evaluate next (protocol v4
    /// `suggest`); `bounds` optionally overrides the snapshot-derived
    /// search box with an explicit `lo,hi` pair per dimension.
    pub fn suggest(
        &mut self,
        model: Option<&str>,
        q: usize,
        bounds: Option<&crate::optimize::Bounds>,
    ) -> Result<Vec<Vec<f64>>> {
        anyhow::ensure!(q >= 1, "suggest needs q ≥ 1");
        let mut line = String::from("suggest ");
        if let Some(m) = model {
            line.push_str(m);
            line.push(' ');
        }
        line.push_str(&q.to_string());
        if let Some(b) = bounds {
            line.push(' ');
            line.push_str(&b.to_string());
        }
        let reply = self.request(&line)?;
        let rest = Self::expect_ok(&reply)?;
        let mut out = Vec::with_capacity(q);
        for part in rest.split(';') {
            out.push(parse_csv_point(part).context("malformed suggest reply")?);
        }
        anyhow::ensure!(
            out.len() == q,
            "server proposed {} points for q={q}",
            out.len()
        );
        Ok(out)
    }

    /// Report an evaluated suggestion back to the server (protocol v4
    /// `tell` — flows through the observe queue into the live model).
    pub fn tell(&mut self, model: Option<&str>, point: &[f64], y: f64) -> Result<()> {
        let mut row: Vec<String> = point.iter().map(f64::to_string).collect();
        row.push(y.to_string());
        let line = match model {
            Some(m) => format!("tell {m} {}", row.join(",")),
            None => format!("tell {}", row.join(",")),
        };
        let reply = self.request(&line)?;
        let rest = Self::expect_ok(&reply)?;
        anyhow::ensure!(rest.starts_with("told"), "unexpected reply: {reply}");
        Ok(())
    }

    /// Raw per-cluster posteriors for a batch (protocol v5 `spredict`):
    /// for each row of `xt`, the `(global_cluster_id, mean, variance)`
    /// triples the server's model answers for, optionally restricted to
    /// `filter`. The scatter-gather side of distributed serving.
    pub fn shard_predict(
        &mut self,
        model: Option<&str>,
        xt: &Matrix,
        filter: Option<&[usize]>,
    ) -> Result<Vec<Vec<(usize, f64, f64)>>> {
        self.shard_predict_traced(model, xt, filter, None)
    }

    /// [`Self::shard_predict`] propagating a trace ID (protocol v7), so
    /// the shard records its spans under the coordinator's trace.
    pub fn shard_predict_traced(
        &mut self,
        model: Option<&str>,
        xt: &Matrix,
        filter: Option<&[usize]>,
        trace: Option<u64>,
    ) -> Result<Vec<Vec<(usize, f64, f64)>>> {
        anyhow::ensure!(xt.rows() >= 1, "shard_predict needs at least one point");
        let body: Vec<String> = (0..xt.rows())
            .map(|i| xt.row(i).iter().map(f64::to_string).collect::<Vec<_>>().join(","))
            .collect();
        let mut line = String::from("spredict ");
        if let Some(m) = model {
            line.push_str(m);
            line.push(' ');
        }
        line.push_str(&format!("{} {}", xt.rows(), body.join(";")));
        if let Some(f) = filter {
            anyhow::ensure!(!f.is_empty(), "empty cluster filter");
            let ids: Vec<String> = f.iter().map(usize::to_string).collect();
            line.push_str(&format!(" clusters={}", ids.join(",")));
        }
        if let Some(t) = trace {
            line.push_str(&format!(" trace={t:x}"));
        }
        let reply = self.request_idempotent(&line)?;
        let rest = Self::expect_ok(&reply)?;
        let rest = rest
            .strip_prefix("spreds ")
            .with_context(|| format!("unexpected reply: {reply}"))?;
        let mut out = Vec::with_capacity(xt.rows());
        for group in rest.split(';') {
            let mut entries = Vec::new();
            for part in group.split('|') {
                let (c, mv) = part.split_once(':').context("malformed spreds entry")?;
                let (m, v) = mv.split_once(',').context("malformed spreds pair")?;
                entries.push((c.parse()?, m.parse()?, v.parse()?));
            }
            out.push(entries);
        }
        anyhow::ensure!(
            out.len() == xt.rows(),
            "server answered {} rows for {} points",
            out.len(),
            xt.rows()
        );
        Ok(out)
    }

    /// Topology handshake (protocol v5 `shardinfo`).
    pub fn shard_info(&mut self, model: Option<&str>) -> Result<ShardInfo> {
        let line = match model {
            Some(m) => format!("shardinfo {m}"),
            None => "shardinfo".to_string(),
        };
        let reply = self.request_idempotent(&line)?;
        let rest = Self::expect_ok(&reply)?;
        let rest = rest
            .strip_prefix("shard ")
            .with_context(|| format!("unexpected reply: {reply}"))?;
        let mut index = None;
        let mut count = None;
        let mut k_total = None;
        let mut dim = None;
        let mut clusters = None;
        let mut algo = None;
        let mut shealth = None;
        for token in rest.split_whitespace() {
            if let Some((i, c)) = token.split_once('/') {
                if index.is_none() && !token.contains('=') {
                    index = Some(i.parse()?);
                    count = Some(c.parse()?);
                    continue;
                }
            }
            if let Some(v) = token.strip_prefix("k=") {
                k_total = Some(v.parse()?);
            } else if let Some(v) = token.strip_prefix("d=") {
                dim = Some(v.parse()?);
            } else if let Some(v) = token.strip_prefix("clusters=") {
                let ids: std::result::Result<Vec<usize>, _> =
                    v.split(',').map(str::parse).collect();
                clusters = Some(ids.context("malformed cluster list")?);
            } else if let Some(v) = token.strip_prefix("algo=") {
                algo = Some(v.to_string());
            } else if let Some(v) = token.strip_prefix("shealth=") {
                shealth = Some(v.to_string());
            }
        }
        Ok(ShardInfo {
            index: index.context("shardinfo reply missing index")?,
            count: count.context("shardinfo reply missing count")?,
            k_total: k_total.context("shardinfo reply missing k")?,
            dim: dim.context("shardinfo reply missing d")?,
            clusters: clusters.context("shardinfo reply missing clusters")?,
            algo: algo.unwrap_or_default(),
            shealth,
        })
    }

    /// Full `metricsx` exposition document (protocol v7) — the line
    /// protocol's one multi-line reply; reads until the `# EOF`
    /// terminator, which is included in the returned text.
    pub fn metricsx(&mut self) -> Result<String> {
        self.writer.write_all(b"metricsx\n")?;
        let mut out = String::new();
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line).map_err(|e| {
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                {
                    anyhow::anyhow!("metricsx timed out mid-document (connection poisoned)")
                } else {
                    anyhow::Error::from(e)
                }
            })?;
            anyhow::ensure!(n > 0, "server closed the connection mid-metricsx");
            if out.is_empty() && line.starts_with("err ") {
                anyhow::bail!("server error: {}", line.trim());
            }
            out.push_str(&line);
            if line.trim_end() == export::EOF_MARKER {
                return Ok(out);
            }
        }
    }

    /// Fetch a stitched trace tree (protocol v7 `trace <id>`): every
    /// retained span of `trace_id` on the answering server — tagged
    /// `local` — plus, on a coordinator, the `shard-<i>` spans collected
    /// from its pool.
    pub fn trace_spans(&mut self, trace_id: u64) -> Result<Vec<WireSpan>> {
        let reply = self.request_idempotent(&format!("trace {trace_id:x}"))?;
        let rest = Self::expect_ok(&reply)?;
        let rest = rest
            .strip_prefix("trace ")
            .with_context(|| format!("unexpected reply: {reply}"))?;
        let mut parts = rest.splitn(3, ' ');
        let id = parts.next().context("trace reply missing id")?;
        anyhow::ensure!(
            u64::from_str_radix(id, 16).ok() == Some(trace_id),
            "server answered for trace {id}, asked for {trace_id:x}"
        );
        let declared: usize = parts.next().context("trace reply missing count")?.parse()?;
        let spans = trace::decode_spans(trace_id, parts.next().unwrap_or(""));
        anyhow::ensure!(
            spans.len() == declared,
            "trace reply declared {declared} spans but decoded {}",
            spans.len()
        );
        Ok(spans)
    }

    /// Recently retained trace IDs on the server, most recent first
    /// (protocol v7 `traces`).
    pub fn recent_traces(&mut self) -> Result<Vec<u64>> {
        let reply = self.request_idempotent("traces")?;
        let rest = Self::expect_ok(&reply)?;
        let rest = rest
            .strip_prefix("traces")
            .with_context(|| format!("unexpected reply: {reply}"))?;
        rest.split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| u64::from_str_radix(t, 16).with_context(|| format!("bad trace id {t:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kriging::Prediction;
    use crate::util::matrix::Matrix;

    struct Sum;
    impl Surrogate for Sum {
        fn predict(&self, xt: &Matrix) -> Result<Prediction> {
            Ok(Prediction {
                mean: (0..xt.rows()).map(|i| xt.row(i).iter().sum()).collect(),
                variance: vec![0.5; xt.rows()],
            })
        }
        fn name(&self) -> &str {
            "sum"
        }
        fn dim(&self) -> usize {
            2
        }
    }

    struct Product;
    impl Surrogate for Product {
        fn predict(&self, xt: &Matrix) -> Result<Prediction> {
            Ok(Prediction {
                mean: (0..xt.rows()).map(|i| xt.row(i).iter().product()).collect(),
                variance: vec![0.25; xt.rows()],
            })
        }
        fn name(&self) -> &str {
            "product"
        }
        fn dim(&self) -> usize {
            2
        }
    }

    fn start_server() -> Server {
        Server::start_with_model(
            Arc::new(Sum),
            ServerConfig { addr: "127.0.0.1:0".into(), batcher: BatcherConfig::default() },
        )
        .unwrap()
    }

    /// Online-capable double: predicts the mean of absorbed targets and
    /// keeps the absorbed points as its training snapshot.
    struct Running {
        dim: usize,
        xs: std::sync::Mutex<Vec<f64>>,
        ys: std::sync::Mutex<Vec<f64>>,
    }

    impl Running {
        fn new(dim: usize) -> Self {
            Self {
                dim,
                xs: std::sync::Mutex::new(Vec::new()),
                ys: std::sync::Mutex::new(Vec::new()),
            }
        }
    }

    impl Surrogate for Running {
        fn predict(&self, xt: &Matrix) -> Result<crate::kriging::Prediction> {
            let ys = self.ys.lock().unwrap();
            let mean =
                if ys.is_empty() { 0.0 } else { ys.iter().sum::<f64>() / ys.len() as f64 };
            Ok(crate::kriging::Prediction {
                mean: vec![mean; xt.rows()],
                variance: vec![1.0; xt.rows()],
            })
        }
        fn name(&self) -> &str {
            "running"
        }
        fn dim(&self) -> usize {
            self.dim
        }
        fn observer(&self) -> Option<&dyn crate::online::OnlineObserver> {
            Some(self)
        }
    }

    impl crate::online::OnlineObserver for Running {
        fn observe_batch(&self, xs: &Matrix, ys: &[f64]) -> Result<()> {
            anyhow::ensure!(xs.cols() == self.dim);
            self.xs.lock().unwrap().extend_from_slice(xs.as_slice());
            self.ys.lock().unwrap().extend_from_slice(ys);
            Ok(())
        }
        fn online_stats(&self) -> crate::online::OnlineStats {
            let n = self.ys.lock().unwrap().len();
            crate::online::OnlineStats {
                observed: n as u64,
                train_points: n,
                history_len: n,
                resident_bytes: n * (self.dim + 1) * std::mem::size_of::<f64>(),
                ..Default::default()
            }
        }
        fn training_snapshot(&self) -> Option<(Matrix, Vec<f64>)> {
            let ys = self.ys.lock().unwrap().clone();
            let xs = self.xs.lock().unwrap().clone();
            Some((Matrix::from_vec(ys.len(), self.dim, xs), ys))
        }
    }

    #[test]
    fn ping_and_stats() {
        let server = start_server();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        assert_eq!(c.request("ping").unwrap(), "ok pong");
        let stats = c.request("stats").unwrap();
        assert!(stats.starts_with("ok requests="), "{stats}");
        // v3: slot names ride the stats reply.
        assert!(stats.contains("observes=0"), "{stats}");
        assert!(stats.contains("slots=default"), "{stats}");
        assert!(stats.contains("default=default"), "{stats}");
    }

    #[test]
    fn observe_roundtrip_updates_served_model() {
        let server = Server::start_with_model(
            Arc::new(Running::new(2)),
            ServerConfig { addr: "127.0.0.1:0".into(), batcher: BatcherConfig::default() },
        )
        .unwrap();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        // observe <x1>,<x2>,<y>
        assert_eq!(c.request("observe 1.0,2.0,10").unwrap(), "ok observed 1");
        assert_eq!(c.observe_batch(None, &[vec![0.0, 0.0]], &[20.0]).unwrap(), 1);
        c.observe(&[5.0, 5.0], 30.0).unwrap();
        // The served posterior reflects all three observations.
        let (mean, _) = c.predict(&[9.0, 9.0]).unwrap();
        assert_eq!(mean, 20.0);
        let stats = c.stats().unwrap();
        assert!(stats.contains("observes=3"), "{stats}");
        assert_eq!(
            server.metrics.observes.load(std::sync::atomic::Ordering::Relaxed),
            3
        );
    }

    #[test]
    fn stats_and_health_report_model_memory() {
        let server = Server::start_with_model(
            Arc::new(Running::new(2)),
            ServerConfig { addr: "127.0.0.1:0".into(), batcher: BatcherConfig::default() },
        )
        .unwrap();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        c.observe(&[1.0, 2.0], 10.0).unwrap();
        c.observe(&[3.0, 4.0], 20.0).unwrap();
        // Per-slot history length + resident bytes ride the stats reply…
        let stats = c.stats().unwrap();
        assert!(
            stats.contains("[points=2 history=2 bytes=48 evicted=0 refit=idle last_refit=0us]"),
            "{stats}"
        );
        // …and the aggregates ride health, next to the existing fields.
        let health = c.request("health").unwrap();
        assert!(health.contains("model_points=2"), "{health}");
        assert!(health.contains("model_bytes=48"), "{health}");
        assert!(health.contains("refits_in_flight=0"), "{health}");
    }

    #[test]
    fn observe_protocol_errors() {
        let server = start_server(); // Sum double: not online-capable
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        let reply = c.request("observe 1.0,2.0,3.0").unwrap();
        assert!(reply.starts_with("err"), "{reply}");
        assert!(reply.contains("not online-capable"), "{reply}");

        let server = Server::start_with_model(
            Arc::new(Running::new(2)),
            ServerConfig { addr: "127.0.0.1:0".into(), batcher: BatcherConfig::default() },
        )
        .unwrap();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        // A bare target with no features is malformed.
        assert!(c.request("observe 1.0").unwrap().starts_with("err"));
        // Count mismatch and ragged rows are protocol errors.
        assert!(c.request("observeb 2 1,2,3").unwrap().starts_with("err"));
        assert!(c.request("observeb 2 1,2,3;4,5").unwrap().starts_with("err"));
        // Unknown slot.
        assert!(c.request("observe nope 1,2,3").unwrap().starts_with("err"));
        // Wrong dimensionality (model expects 2 features + target).
        assert!(c.request("observe 1,2,3,4").unwrap().starts_with("err"));
    }

    #[test]
    fn suggest_proposes_points_inside_bounds() {
        let server = Server::start_with_model(
            Arc::new(Running::new(2)),
            ServerConfig { addr: "127.0.0.1:0".into(), batcher: BatcherConfig::default() },
        )
        .unwrap();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        // Build a history first; suggest derives bounds from it.
        c.observe(&[0.0, 0.0], 10.0).unwrap();
        c.observe(&[2.0, 2.0], 5.0).unwrap();
        c.observe(&[1.0, 1.0], 20.0).unwrap();
        let points = c.suggest(None, 3, None).unwrap();
        assert_eq!(points.len(), 3);
        // Snapshot bounds: [0, 2] per dim expanded 5% per side.
        for p in &points {
            assert_eq!(p.len(), 2);
            assert!(
                p.iter().all(|&v| (-0.1..=2.1).contains(&v)),
                "proposal escaped snapshot bounds: {p:?}"
            );
        }
        // Explicit bounds override the snapshot box.
        let tight =
            crate::optimize::Bounds::new(vec![0.5, 0.5], vec![0.6, 0.6]).unwrap();
        let points = c.suggest(None, 2, Some(&tight)).unwrap();
        for p in &points {
            assert!(tight.contains(p), "proposal escaped explicit bounds: {p:?}");
        }
        assert_eq!(server.metrics.suggests.load(std::sync::atomic::Ordering::Relaxed), 5);
        let stats = c.stats().unwrap();
        assert!(stats.contains("suggests=5"), "{stats}");
    }

    #[test]
    fn suggest_protocol_errors() {
        // Fit-once slots have no snapshot → suggest is rejected.
        let server = start_server();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        let reply = c.request("suggest 2").unwrap();
        assert!(reply.starts_with("err"), "{reply}");
        assert!(reply.contains("not online-capable"), "{reply}");

        let server = Server::start_with_model(
            Arc::new(Running::new(2)),
            ServerConfig { addr: "127.0.0.1:0".into(), batcher: BatcherConfig::default() },
        )
        .unwrap();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        // Empty history: no incumbent to improve on yet.
        assert!(c.request("suggest 1").unwrap().starts_with("err"));
        c.observe(&[0.0, 0.0], 1.0).unwrap();
        // Malformed counts / bounds / slots.
        assert!(c.request("suggest 0").unwrap().starts_with("err"));
        assert!(c.request("suggest abc xyz").unwrap().starts_with("err"));
        assert!(c.request("suggest 1 2,1;0,1").unwrap().starts_with("err"), "inverted");
        assert!(c.request("suggest nope 1").unwrap().starts_with("err"));
        // Bounds with the wrong dimensionality.
        assert!(c.request("suggest 1 0,1").unwrap().starts_with("err"));
    }

    #[test]
    fn tell_rides_the_observe_path() {
        let server = Server::start_with_model(
            Arc::new(Running::new(2)),
            ServerConfig { addr: "127.0.0.1:0".into(), batcher: BatcherConfig::default() },
        )
        .unwrap();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        assert_eq!(c.request("tell 1.0,2.0,10").unwrap(), "ok told 1");
        c.tell(None, &[3.0, 4.0], 30.0).unwrap();
        // Both tells reached the model through the observe queue.
        let (mean, _) = c.predict(&[0.0, 0.0]).unwrap();
        assert_eq!(mean, 20.0);
        assert_eq!(
            server.metrics.observes.load(std::sync::atomic::Ordering::Relaxed),
            2
        );
        // Shape and capability errors mirror observe's.
        assert!(c.request("tell 1.0").unwrap().starts_with("err"));
        assert!(c.request("tell nope 1,2,3").unwrap().starts_with("err"));
        let plain = start_server();
        let mut c = Client::connect(&plain.local_addr.to_string()).unwrap();
        assert!(c.request("tell 1,2,3").unwrap().starts_with("err"));
    }

    #[test]
    fn predict_roundtrip() {
        let server = start_server();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        let (mean, var) = c.predict(&[1.5, 2.5]).unwrap();
        assert_eq!(mean, 4.0);
        assert_eq!(var, 0.5);
        // v1 form still served.
        assert_eq!(c.request("predict 1.5,2.5").unwrap(), "ok 4,0.5");
    }

    #[test]
    fn predictb_roundtrip() {
        let server = start_server();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        let out = c
            .predict_batch(None, &[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 5.0]])
            .unwrap();
        assert_eq!(out, vec![(3.0, 0.5), (7.0, 0.5), (10.0, 0.5)]);
        // Count mismatch is a protocol error.
        assert!(c.request("predictb 2 1,2").unwrap().starts_with("err"));
        assert!(c.request("predictb 2 1,2;3").unwrap().starts_with("err"));
    }

    #[test]
    fn models_and_named_predict() {
        let server = start_server();
        server.registry().insert("prod", Arc::new(Product));
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        let listing = c.models().unwrap();
        assert!(listing.starts_with("default=default"), "{listing}");
        assert!(listing.contains("default:sum:d2"), "{listing}");
        assert!(listing.contains("prod:product:d2"), "{listing}");
        // Named predict hits the named slot, default stays.
        assert_eq!(c.request("predict prod 3,4").unwrap(), "ok 12,0.25");
        assert_eq!(c.request("predict 3,4").unwrap(), "ok 7,0.5");
        let out = c.predict_batch(Some("prod"), &[vec![2.0, 3.0]]).unwrap();
        assert_eq!(out, vec![(6.0, 0.25)]);
    }

    #[test]
    fn swap_switches_default_under_live_connection() {
        let server = start_server();
        server.registry().insert("v2", Arc::new(Product));
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        assert_eq!(c.predict(&[2.0, 5.0]).unwrap().0, 7.0); // sum
        c.swap("v2").unwrap();
        assert_eq!(c.predict(&[2.0, 5.0]).unwrap().0, 10.0); // product
        assert!(c.swap("missing").is_err());
    }

    #[test]
    fn protocol_errors_reported() {
        let server = start_server();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        assert!(c.request("predict 1,abc").unwrap().starts_with("err"));
        assert!(c.request("bogus").unwrap().starts_with("err"));
        // Wrong dimensionality → batcher rejects.
        assert!(c.request("predict 1").unwrap().starts_with("err"));
        // Unknown model slot.
        assert!(c.request("predict nope 1,2").unwrap().starts_with("err"));
        // Load of a nonexistent artifact.
        assert!(c.request("load /no/such/artifact.ck").unwrap().starts_with("err"));
        assert!(server.metrics.errors.load(std::sync::atomic::Ordering::Relaxed) >= 5);
    }

    #[test]
    fn concurrent_clients() {
        let server = start_server();
        let addr = server.local_addr.to_string();
        let mut handles = Vec::new();
        for i in 0..8 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                for j in 0..10 {
                    let (mean, _) = c.predict(&[i as f64, j as f64]).unwrap();
                    assert_eq!(mean, (i + j) as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            server.metrics.predictions.load(std::sync::atomic::Ordering::Relaxed),
            80
        );
    }

    #[test]
    fn health_op_reports_ready() {
        let server = start_server();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        let reply = c.request("health").unwrap();
        assert!(reply.starts_with("ok health ready=true draining=false"), "{reply}");
        assert!(reply.contains("depth="), "{reply}");
        assert!(reply.contains("panics=0"), "{reply}");
        // No WAL or shard pool attached → those fields stay absent.
        assert!(!reply.contains("wal_seq="), "{reply}");
        assert!(!reply.contains("shards_alive="), "{reply}");
    }

    #[test]
    fn retry_recovers_idempotent_request_after_connection_drop() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let fake = std::thread::spawn(move || {
            // First connection dies without replying; the second serves.
            let (first, _) = listener.accept().unwrap();
            drop(first);
            let (mut conn, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.starts_with("predictb"), "{line}");
            conn.write_all(b"ok 3,0.5\n").unwrap();
        });
        let mut c = Client::connect(&addr).unwrap().with_retry(RetryPolicy {
            max_retries: 2,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(5),
            seed: 7,
        });
        let out = c.predict_batch(None, &[[1.0, 2.0]]).unwrap();
        assert_eq!(out, vec![(3.0, 0.5)]);
        fake.join().unwrap();
    }

    #[test]
    fn without_retry_a_dropped_connection_is_an_error() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let fake = std::thread::spawn(move || {
            let (first, _) = listener.accept().unwrap();
            drop(first);
        });
        let mut c = Client::connect(&addr).unwrap();
        assert!(c.predict_batch(None, &[[1.0, 2.0]]).is_err());
        fake.join().unwrap();
    }

    #[test]
    fn stats_and_health_carry_process_identity() {
        let server = start_server();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        for reply in [c.request("stats").unwrap(), c.request("health").unwrap()] {
            assert!(reply.contains("uptime_s="), "{reply}");
            assert!(reply.contains("started_unix="), "{reply}");
            assert!(
                reply.contains(&format!("version={}", ServerMetrics::version())),
                "{reply}"
            );
        }
    }

    #[test]
    fn metricsx_emits_parseable_exposition() {
        let server = Server::start_with_model(
            Arc::new(Running::new(2)),
            ServerConfig { addr: "127.0.0.1:0".into(), batcher: BatcherConfig::default() },
        )
        .unwrap();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        c.observe(&[1.0, 2.0], 10.0).unwrap();
        c.predict(&[0.0, 0.0]).unwrap();
        let text = c.metricsx().unwrap();
        assert!(text.trim_end().ends_with(export::EOF_MARKER), "{text}");
        // The parse-everything gate: every non-comment line must be a
        // well-formed sample.
        let samples = export::parse(&text).unwrap();
        let get = |name: &str| samples.iter().find(|s| s.name == name);
        assert_eq!(get("ckrig_predictions_total").unwrap().value, 1.0);
        assert_eq!(get("ckrig_observes_total").unwrap().value, 1.0);
        assert!(get("ckrig_uptime_seconds").is_some());
        assert!(get("ckrig_ready").unwrap().value == 1.0);
        let build = get("ckrig_build_info").unwrap();
        assert!(build.labels.iter().any(|(k, v)| k == "version" && !v.is_empty()));
        // Per-model quality gauges carry the slot label.
        let cov = get("ckrig_model_coverage95").unwrap();
        assert_eq!(cov.labels, vec![("model".to_string(), "default".to_string())]);
        assert!(get("ckrig_model_quality_scored_total").is_some());
        assert!(samples.iter().any(|s| s.name == "ckrig_op_latency_us_bucket"));
        // No WAL/pool attached → those gauges stay absent.
        assert!(get("ckrig_wal_last_seq").is_none());
        assert!(get("ckrig_shards_total").is_none());
        // The connection still serves line ops after the multi-line reply.
        assert_eq!(c.request("ping").unwrap(), "ok pong");
    }

    #[test]
    fn forced_trace_records_and_answers_tree() {
        let server = start_server();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        let id = 0xabc123;
        c.predict_batch_traced(None, &[vec![1.0, 2.0]], Some(id)).unwrap();
        let spans = c.trace_spans(id).unwrap();
        let names: Vec<&str> = spans.iter().map(|w| w.span.name.as_str()).collect();
        for want in ["predictb", "queue-wait", "batch-assembly", "predict"] {
            assert!(names.contains(&want), "missing {want:?} in {names:?}");
        }
        assert!(spans.iter().all(|w| w.proc == "local"), "{spans:?}");
        // The root span parents every flush span.
        let root = spans.iter().find(|w| w.span.name == "predictb").unwrap();
        assert_eq!(root.span.parent_id, 0);
        assert!(spans
            .iter()
            .filter(|w| w.span.name != "predictb")
            .all(|w| w.span.parent_id == root.span.span_id));
        // `traces` lists the retained ID; unknown traces answer empty;
        // malformed IDs are protocol errors.
        assert!(c.recent_traces().unwrap().contains(&id));
        assert_eq!(c.trace_spans(0xdead).unwrap().len(), 0);
        assert!(c.request("trace zzz").unwrap().starts_with("err"));
        assert!(c.request("predictb 1 1,2 trace=0").unwrap().starts_with("err"));
    }

    #[test]
    fn sampler_mints_traces_without_client_cooperation() {
        use crate::obs::trace::Sampling;
        let server = Server::start_with_options(
            Arc::new(ModelRegistry::new("default", Arc::new(Sum))),
            ServerConfig { addr: "127.0.0.1:0".into(), batcher: BatcherConfig::default() },
            ServeOptions {
                tracer: Arc::new(Tracer::new(256, Sampling::Always)),
                ..Default::default()
            },
        )
        .unwrap();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        // A plain predictb (no trace= token) still gets sampled.
        c.predict_batch(None, &[vec![1.0, 2.0]]).unwrap();
        let ids = c.recent_traces().unwrap();
        assert_eq!(ids.len(), 1, "{ids:?}");
        let spans = c.trace_spans(ids[0]).unwrap();
        assert!(spans.iter().any(|w| w.span.name == "predictb"), "{spans:?}");
        // With the default (disabled) tracer, nothing is minted.
        let plain = start_server();
        let mut c = Client::connect(&plain.local_addr.to_string()).unwrap();
        c.predict_batch(None, &[vec![1.0, 2.0]]).unwrap();
        assert!(c.recent_traces().unwrap().is_empty());
    }
}
