//! TCP prediction server — the leader process of the coordinator.
//!
//! Line protocol (one request per line, CSV):
//!   `predict <x1>,<x2>,...`   → `ok <mean>,<variance>`
//!   `stats`                   → `ok <metrics summary>`
//!   `ping`                    → `ok pong`
//!   anything else             → `err <message>`
//!
//! Requests funnel through the [`Batcher`], so concurrent clients are
//! served in dynamically-formed micro-batches. The fitted model is
//! immutable after startup — no locks on the hot path besides the queue.

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::metrics::ServerMetrics;
use crate::kriging::Surrogate;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

pub struct ServerConfig {
    pub addr: String,
    pub batcher: BatcherConfig,
    /// Input dimension the model expects.
    pub dim: usize,
}

/// A running prediction server.
pub struct Server {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<ServerMetrics>,
}

impl Server {
    /// Bind and serve in background threads (one per connection).
    pub fn start(model: Arc<dyn Surrogate>, cfg: ServerConfig) -> Result<Self> {
        let metrics = Arc::new(ServerMetrics::new());
        let batcher =
            Arc::new(Batcher::start(model, cfg.dim, cfg.batcher.clone(), metrics.clone()));
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));

        let accept_stop = stop.clone();
        let accept_metrics = metrics.clone();
        let accept_thread = std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !accept_stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let b = batcher.clone();
                        let m = accept_metrics.clone();
                        let s = accept_stop.clone();
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_connection(stream, b, m, s);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });

        Ok(Self { local_addr, stop, accept_thread: Some(accept_thread), metrics })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_connection(
    stream: TcpStream,
    batcher: Arc<Batcher>,
    metrics: Arc<ServerMetrics>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    // Line-sized writes + request/response ping-pong: Nagle + delayed ACK
    // would add ~40 ms per round trip (§Perf iteration 5).
    stream.set_nodelay(true)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {
                let reply = dispatch(line.trim(), &batcher, &metrics);
                writer.write_all(reply.as_bytes())?;
                writer.write_all(b"\n")?;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => return Ok(()),
        }
    }
}

/// Parse and execute one protocol line.
fn dispatch(line: &str, batcher: &Batcher, metrics: &ServerMetrics) -> String {
    metrics.record_request();
    if line == "ping" {
        return "ok pong".into();
    }
    if line == "stats" {
        return format!("ok {}", metrics.summary());
    }
    if let Some(rest) = line.strip_prefix("predict ") {
        let parsed: Result<Vec<f64>, _> =
            rest.split(',').map(|f| f.trim().parse::<f64>()).collect();
        return match parsed {
            Ok(point) => match batcher.predict_one(&point) {
                Ok((mean, var)) => format!("ok {mean},{var}"),
                Err(e) => {
                    metrics.record_error();
                    format!("err {e:#}")
                }
            },
            Err(e) => {
                metrics.record_error();
                format!("err bad number: {e}")
            }
        };
    }
    metrics.record_error();
    format!("err unknown command {line:?}")
}

/// Minimal blocking client for tests/examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }

    pub fn request(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        Ok(reply.trim().to_string())
    }

    pub fn predict(&mut self, point: &[f64]) -> Result<(f64, f64)> {
        let body: Vec<String> = point.iter().map(|v| v.to_string()).collect();
        let reply = self.request(&format!("predict {}", body.join(",")))?;
        let rest = reply
            .strip_prefix("ok ")
            .with_context(|| format!("server error: {reply}"))?;
        let (m, v) = rest.split_once(',').context("malformed reply")?;
        Ok((m.parse()?, v.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kriging::Prediction;
    use crate::util::matrix::Matrix;

    struct Sum;
    impl Surrogate for Sum {
        fn predict(&self, xt: &Matrix) -> Result<Prediction> {
            Ok(Prediction {
                mean: (0..xt.rows()).map(|i| xt.row(i).iter().sum()).collect(),
                variance: vec![0.5; xt.rows()],
            })
        }
        fn name(&self) -> &str {
            "sum"
        }
    }

    fn start_server() -> Server {
        Server::start(
            Arc::new(Sum),
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                batcher: BatcherConfig::default(),
                dim: 2,
            },
        )
        .unwrap()
    }

    #[test]
    fn ping_and_stats() {
        let server = start_server();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        assert_eq!(c.request("ping").unwrap(), "ok pong");
        assert!(c.request("stats").unwrap().starts_with("ok requests="));
    }

    #[test]
    fn predict_roundtrip() {
        let server = start_server();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        let (mean, var) = c.predict(&[1.5, 2.5]).unwrap();
        assert_eq!(mean, 4.0);
        assert_eq!(var, 0.5);
    }

    #[test]
    fn protocol_errors_reported() {
        let server = start_server();
        let mut c = Client::connect(&server.local_addr.to_string()).unwrap();
        assert!(c.request("predict 1,abc").unwrap().starts_with("err"));
        assert!(c.request("bogus").unwrap().starts_with("err"));
        // Wrong dimensionality → batcher rejects.
        assert!(c.request("predict 1").unwrap().starts_with("err"));
        assert!(server.metrics.errors.load(std::sync::atomic::Ordering::Relaxed) >= 3);
    }

    #[test]
    fn concurrent_clients() {
        let server = start_server();
        let addr = server.local_addr.to_string();
        let mut handles = Vec::new();
        for i in 0..8 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                for j in 0..10 {
                    let (mean, _) = c.predict(&[i as f64, j as f64]).unwrap();
                    assert_eq!(mean, (i + j) as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            server.metrics.predictions.load(std::sync::atomic::Ordering::Relaxed),
            80
        );
    }
}
