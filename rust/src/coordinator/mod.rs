//! The serving coordinator: dynamic micro-batching, a TCP line-protocol
//! prediction server with hot-swappable model slots, and serving metrics.
//! Fitted models (native or PJRT backend) live in a [`ModelRegistry`] and
//! sit behind the [`Batcher`]; python is never on this path. Artifacts
//! written by [`crate::kriging::Surrogate::save`] boot the server through
//! the protocol's `load`/`swap` ops without a refit or restart.

pub mod batcher;
pub mod metrics;
pub mod registry;
pub mod server;
pub mod shardpool;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::{ProtocolOp, ServerMetrics};
pub use registry::{ModelInfo, ModelRegistry};
pub use server::{Client, Health, RetryPolicy, ServeOptions, Server, ServerConfig, ShardInfo};
pub use shardpool::{ShardPool, ShardPoolConfig};
