//! The serving coordinator: dynamic micro-batching, a TCP line-protocol
//! prediction server and serving metrics. The fitted Cluster Kriging
//! model (native or PJRT backend) sits behind the [`Batcher`]; python is
//! never on this path.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::ServerMetrics;
pub use server::{Client, Server, ServerConfig};
