//! Dynamic micro-batching for the prediction service.
//!
//! Individual predict requests are cheap per point but the per-call
//! overhead (cross-covariance assembly, PJRT dispatch on the AOT path)
//! amortizes heavily over a batch — the same motivation as dynamic
//! batching in model-serving systems (vLLM/Triton). Requests are queued;
//! a worker flushes when `max_batch` is reached or the oldest request has
//! waited `max_wait`, then runs one batched `Surrogate::predict`.
//!
//! The batched matrix lands in `OrdinaryKriging::predict`, whose chunks
//! assemble cross-correlations through `Kernel::cross_corr_fast` — the
//! GEMM-trick path for the SE kernel, row-parallel scalar otherwise — so
//! batching here compounds with the vectorized assembly downstream.

use crate::kriging::Surrogate;
use crate::util::matrix::Matrix;
use crate::coordinator::metrics::ServerMetrics;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued request: a point and a reply channel.
struct Pending {
    point: Vec<f64>,
    reply: Sender<anyhow::Result<(f64, f64)>>,
    enqueued: Instant,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

struct Shared {
    queue: Mutex<Vec<Pending>>,
    available: Condvar,
    shutdown: Mutex<bool>,
}

/// A running batcher: handle to enqueue requests + its worker thread.
pub struct Batcher {
    shared: Arc<Shared>,
    worker: Option<std::thread::JoinHandle<()>>,
    dim: usize,
}

impl Batcher {
    /// Spawn the batching worker over a fitted model.
    pub fn start(
        model: Arc<dyn Surrogate>,
        dim: usize,
        cfg: BatcherConfig,
        metrics: Arc<ServerMetrics>,
    ) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            available: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let worker_shared = shared.clone();
        let worker = std::thread::spawn(move || {
            worker_loop(worker_shared, model, cfg, metrics);
        });
        Self { shared, worker: Some(worker), dim }
    }

    /// Enqueue one point; blocks until its prediction is ready.
    pub fn predict_one(&self, point: &[f64]) -> anyhow::Result<(f64, f64)> {
        anyhow::ensure!(point.len() == self.dim, "expected {} dims, got {}", self.dim, point.len());
        let (tx, rx): (Sender<anyhow::Result<(f64, f64)>>, Receiver<_>) = channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push(Pending { point: point.to_vec(), reply: tx, enqueued: Instant::now() });
        }
        self.shared.available.notify_one();
        rx.recv().map_err(|_| anyhow::anyhow!("batcher dropped request"))?
    }

    /// Current queue depth (diagnostics / backpressure decisions).
    pub fn depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    shared: Arc<Shared>,
    model: Arc<dyn Surrogate>,
    cfg: BatcherConfig,
    metrics: Arc<ServerMetrics>,
) {
    loop {
        // Collect a batch: wait for work, then linger up to max_wait for
        // more requests (or until the batch is full).
        let batch: Vec<Pending> = {
            let mut q = shared.queue.lock().unwrap();
            while q.is_empty() {
                if *shared.shutdown.lock().unwrap() {
                    return;
                }
                let (guard, _timeout) =
                    shared.available.wait_timeout(q, Duration::from_millis(50)).unwrap();
                q = guard;
            }
            let oldest = q[0].enqueued;
            // Linger while under max_batch and under max_wait.
            while q.len() < cfg.max_batch && oldest.elapsed() < cfg.max_wait {
                let (guard, timeout) = shared
                    .available
                    .wait_timeout(q, cfg.max_wait.saturating_sub(oldest.elapsed()))
                    .unwrap();
                q = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = q.len().min(cfg.max_batch);
            q.drain(..take).collect()
        };

        if batch.is_empty() {
            continue;
        }

        // Build the batch matrix and run one predict.
        let d = batch[0].point.len();
        let mut data = Vec::with_capacity(batch.len() * d);
        for p in &batch {
            data.extend_from_slice(&p.point);
        }
        let xt = Matrix::from_vec(batch.len(), d, data);
        let t0 = Instant::now();
        match model.predict(&xt) {
            Ok(pred) => {
                metrics.record_batch(batch.len(), t0.elapsed().as_secs_f64());
                for (i, p) in batch.into_iter().enumerate() {
                    let _ = p.reply.send(Ok((pred.mean[i], pred.variance[i])));
                }
            }
            Err(e) => {
                metrics.record_error();
                for p in batch {
                    let _ = p.reply.send(Err(anyhow::anyhow!("predict failed: {e:#}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kriging::Prediction;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Test double: records batch sizes, returns x[0] as mean.
    struct Echo {
        calls: AtomicUsize,
        max_batch_seen: AtomicUsize,
    }

    impl Surrogate for Echo {
        fn predict(&self, xt: &Matrix) -> anyhow::Result<Prediction> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            self.max_batch_seen.fetch_max(xt.rows(), Ordering::SeqCst);
            Ok(Prediction {
                mean: (0..xt.rows()).map(|i| xt[(i, 0)]).collect(),
                variance: vec![1.0; xt.rows()],
            })
        }

        fn name(&self) -> &str {
            "echo"
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let model = Arc::new(Echo { calls: AtomicUsize::new(0), max_batch_seen: AtomicUsize::new(0) });
        let b = Batcher::start(model.clone(), 2, BatcherConfig::default(), Arc::new(ServerMetrics::new()));
        let (mean, var) = b.predict_one(&[3.5, 1.0]).unwrap();
        assert_eq!(mean, 3.5);
        assert_eq!(var, 1.0);
        drop(b);
        assert_eq!(model.calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let model = Arc::new(Echo { calls: AtomicUsize::new(0), max_batch_seen: AtomicUsize::new(0) });
        let b = Batcher::start(model, 3, BatcherConfig::default(), Arc::new(ServerMetrics::new()));
        assert!(b.predict_one(&[1.0]).is_err());
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let model = Arc::new(Echo { calls: AtomicUsize::new(0), max_batch_seen: AtomicUsize::new(0) });
        let cfg = BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(20) };
        let metrics = Arc::new(ServerMetrics::new());
        let b = Arc::new(Batcher::start(model.clone(), 1, cfg, metrics.clone()));
        let mut handles = Vec::new();
        for i in 0..40 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                b.predict_one(&[i as f64]).unwrap()
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let (mean, _) = h.join().unwrap();
            assert_eq!(mean, i as f64);
        }
        // 40 concurrent requests should need far fewer than 40 predict
        // calls (batched), and at least one batch bigger than 1.
        let calls = model.calls.load(Ordering::SeqCst);
        assert!(calls < 40, "no batching happened ({calls} calls)");
        assert!(model.max_batch_seen.load(Ordering::SeqCst) > 1);
        assert!(metrics.predictions.load(Ordering::Relaxed) == 40);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let model = Arc::new(Echo { calls: AtomicUsize::new(0), max_batch_seen: AtomicUsize::new(0) });
        let b = Batcher::start(model, 1, BatcherConfig::default(), Arc::new(ServerMetrics::new()));
        assert_eq!(b.depth(), 0);
        drop(b); // must not hang
    }
}
