//! Dynamic micro-batching for the prediction service.
//!
//! Individual predict requests are cheap per point but the per-call
//! overhead (cross-covariance assembly, PJRT dispatch on the AOT path)
//! amortizes heavily over a batch — the same motivation as dynamic
//! batching in model-serving systems (vLLM/Triton). Requests are queued;
//! a worker flushes when `max_batch` *points* have accumulated or the
//! oldest request has waited `max_wait`, groups the flush by target
//! model (requests name a [`crate::coordinator::ModelRegistry`] slot, or
//! ride the current default), and runs one batched
//! [`Surrogate::predict_into`] per group into worker-owned buffers —
//! allocation-free on the steady-state hot path.
//!
//! Requests may carry several points (`predictb`), which join the same
//! flush: a 40-point client batch and 24 single-point requests form one
//! 64-row matrix if they target the same model.

use crate::coordinator::metrics::{ProtocolOp, ServerMetrics};
use crate::coordinator::registry::ModelRegistry;
use crate::kriging::Surrogate;
use crate::obs::trace::{self, TraceCtx};
use crate::online::wal::Durability;
use crate::util::matrix::Matrix;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// What a queued request asks the flush to do with its rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReqKind {
    /// Predict the rows; reply carries one `(mean, variance)` per row.
    Predict,
    /// Absorb the rows as observations (each row is `dim` features
    /// followed by the target); reply is empty on success.
    Observe,
}

/// One queued request: one or more points for one model slot.
struct Pending {
    kind: ReqKind,
    /// Row-major values: `rows × dim` for predicts, `rows × (dim + 1)`
    /// for observes (features then target per row).
    data: Vec<f64>,
    rows: usize,
    /// The target model's input dimensionality at enqueue time (row
    /// width follows from `kind`).
    dim: usize,
    /// Target slot; `None` rides the default at flush time.
    model: Option<String>,
    reply: Sender<anyhow::Result<Vec<(f64, f64)>>>,
    enqueued: Instant,
    /// Trace context of a sampled/forced request ([`crate::obs::trace`]):
    /// the flush records this request's queue-wait under it and bills the
    /// shared batch work to the first traced request in the group.
    trace: Option<TraceCtx>,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Flush threshold in *points* (not requests).
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

struct Shared {
    queue: Mutex<Vec<Pending>>,
    available: Condvar,
    shutdown: Mutex<bool>,
}

/// A running batcher: handle to enqueue requests + its worker thread.
pub struct Batcher {
    shared: Arc<Shared>,
    worker: Option<std::thread::JoinHandle<()>>,
    registry: Arc<ModelRegistry>,
}

impl Batcher {
    /// Spawn the batching worker over a model registry.
    pub fn start(
        registry: Arc<ModelRegistry>,
        cfg: BatcherConfig,
        metrics: Arc<ServerMetrics>,
    ) -> Self {
        Self::start_with_wal(registry, cfg, metrics, None)
    }

    /// Spawn the batching worker with an optional write-ahead log:
    /// when present, every observe request is appended (and fsynced per
    /// the log's policy) **before** it is applied to the model, so an
    /// `ok` reply implies the observation survives a crash.
    pub fn start_with_wal(
        registry: Arc<ModelRegistry>,
        cfg: BatcherConfig,
        metrics: Arc<ServerMetrics>,
        wal: Option<Arc<Durability>>,
    ) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            available: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let worker_shared = shared.clone();
        let worker_registry = registry.clone();
        let worker = std::thread::spawn(move || {
            worker_loop(worker_shared, worker_registry, cfg, metrics, wal);
        });
        Self { shared, worker: Some(worker), registry }
    }

    /// The registry this batcher resolves models from.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Enqueue one point for the default model; blocks until predicted.
    pub fn predict_one(&self, point: &[f64]) -> anyhow::Result<(f64, f64)> {
        self.predict_one_for(None, point)
    }

    /// Enqueue one point for a named model slot.
    pub fn predict_one_for(
        &self,
        model: Option<&str>,
        point: &[f64],
    ) -> anyhow::Result<(f64, f64)> {
        let out = self.predict_rows(model, point.to_vec(), 1)?;
        Ok(out[0])
    }

    /// Enqueue `rows` points (row-major `data`, `rows × dim` values) for
    /// one model slot; blocks until the whole request is predicted.
    /// Dimensions are validated against the target model at enqueue time.
    pub fn predict_rows(
        &self,
        model: Option<&str>,
        data: Vec<f64>,
        rows: usize,
    ) -> anyhow::Result<Vec<(f64, f64)>> {
        self.enqueue(ReqKind::Predict, model, data, rows, None)
    }

    /// [`Self::predict_rows`] with a trace context attached: the flush
    /// worker records this request's queue-wait span under `trace` and,
    /// when this is the first traced request of its flush, the shared
    /// batch-assembly and predict spans too.
    pub fn predict_rows_traced(
        &self,
        model: Option<&str>,
        data: Vec<f64>,
        rows: usize,
        trace: Option<TraceCtx>,
    ) -> anyhow::Result<Vec<(f64, f64)>> {
        self.enqueue(ReqKind::Predict, model, data, rows, trace)
    }

    /// Enqueue `rows` observations for one model slot; each row is the
    /// point's `dim` features followed by its target value (`rows ×
    /// (dim+1)` values total). Blocks until the whole request is
    /// absorbed. Joins the same flush queue as predictions, so observes
    /// and predicts from concurrent clients serialize through one worker
    /// with no extra locking on the model hot path.
    pub fn observe_rows(
        &self,
        model: Option<&str>,
        data: Vec<f64>,
        rows: usize,
    ) -> anyhow::Result<()> {
        self.enqueue(ReqKind::Observe, model, data, rows, None).map(|_| ())
    }

    fn enqueue(
        &self,
        kind: ReqKind,
        model: Option<&str>,
        data: Vec<f64>,
        rows: usize,
        trace: Option<TraceCtx>,
    ) -> anyhow::Result<Vec<(f64, f64)>> {
        let target = self
            .registry
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("no model slot named {:?}", model.unwrap_or("")))?;
        let dim = target.dim();
        let width = match kind {
            ReqKind::Predict => dim,
            ReqKind::Observe => dim + 1,
        };
        anyhow::ensure!(rows >= 1, "request has no points");
        anyhow::ensure!(
            data.len() == rows * width,
            "expected {rows}×{width} values for model {:?}, got {}",
            model.unwrap_or("default"),
            data.len()
        );
        if kind == ReqKind::Observe {
            anyhow::ensure!(
                target.observer().is_some(),
                "model slot {:?} is not online-capable",
                model.unwrap_or("default")
            );
        }
        let (tx, rx): (Sender<anyhow::Result<Vec<(f64, f64)>>>, Receiver<_>) = channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push(Pending {
                kind,
                data,
                rows,
                dim,
                model: model.map(str::to_string),
                reply: tx,
                enqueued: Instant::now(),
                trace,
            });
        }
        self.shared.available.notify_one();
        rx.recv().map_err(|_| anyhow::anyhow!("batcher dropped request"))?
    }

    /// Current queue depth in points (diagnostics / backpressure).
    pub fn depth(&self) -> usize {
        self.shared.queue.lock().unwrap().iter().map(|p| p.rows).sum()
    }

    /// Wait until the flush queue is empty (graceful-drain path).
    /// Returns false if `timeout` expired with work still queued.
    pub fn drain(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while self.depth() > 0 {
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        true
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    shared: Arc<Shared>,
    registry: Arc<ModelRegistry>,
    cfg: BatcherConfig,
    metrics: Arc<ServerMetrics>,
    wal: Option<Arc<Durability>>,
) {
    // Worker-owned buffers, reused across flushes: the batch matrix plus
    // the predict_into output pair. Steady state allocates nothing.
    let mut xt_data: Vec<f64> = Vec::new();
    let mut mean_buf: Vec<f64> = Vec::new();
    let mut var_buf: Vec<f64> = Vec::new();

    loop {
        // Collect a batch: wait for work, then linger up to max_wait for
        // more requests (or until the batch is full).
        let batch: Vec<Pending> = {
            let mut q = shared.queue.lock().unwrap();
            while q.is_empty() {
                if *shared.shutdown.lock().unwrap() {
                    return;
                }
                let (guard, _timeout) =
                    shared.available.wait_timeout(q, Duration::from_millis(50)).unwrap();
                q = guard;
            }
            let oldest = q[0].enqueued;
            let points = |q: &Vec<Pending>| q.iter().map(|p| p.rows).sum::<usize>();
            // Linger while under max_batch points and under max_wait.
            while points(&*q) < cfg.max_batch && oldest.elapsed() < cfg.max_wait {
                let (guard, timeout) = shared
                    .available
                    .wait_timeout(q, cfg.max_wait.saturating_sub(oldest.elapsed()))
                    .unwrap();
                q = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            // Drain whole requests until the point budget is covered.
            let mut take = 0;
            let mut taken_points = 0;
            while take < q.len() && taken_points < cfg.max_batch {
                taken_points += q[take].rows;
                take += 1;
            }
            q.drain(..take).collect()
        };

        if batch.is_empty() {
            continue;
        }

        // Resolve the default name ONCE per flush, not per request.
        let default_key = registry.default_name();
        let key_of =
            |p: &Pending| -> &str { p.model.as_deref().unwrap_or(default_key.as_str()) };

        // Steady-state fast path: every request targets the same slot
        // (the overwhelmingly common single-model case) — no grouping
        // map, no per-request key clones.
        let first_key = key_of(&batch[0]).to_string();
        if batch[1..].iter().all(|p| key_of(p) == first_key) {
            // A panicking model must not take the worker thread (and
            // with it every future request) down: contain it, count it,
            // and let the dropped reply channels error the batch out.
            let flushed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                flush_group(
                    &first_key, batch, &registry, &metrics, &mut xt_data, &mut mean_buf,
                    &mut var_buf, wal.as_deref(),
                );
            }));
            if flushed.is_err() {
                metrics.record_panic();
                log::warn!("batch flush for slot {first_key:?} panicked; requests dropped");
            }
            continue;
        }

        // Mixed flush: group by resolved slot name, preserving arrival
        // order within each group.
        let mut order: Vec<String> = Vec::new();
        let mut groups: std::collections::HashMap<String, Vec<Pending>> = Default::default();
        for p in batch {
            let key = p.model.clone().unwrap_or_else(|| default_key.clone());
            if !groups.contains_key(&key) {
                order.push(key.clone());
            }
            groups.entry(key).or_default().push(p);
        }
        for key in order {
            let group = groups.remove(&key).unwrap();
            let flushed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                flush_group(
                    &key, group, &registry, &metrics, &mut xt_data, &mut mean_buf,
                    &mut var_buf, wal.as_deref(),
                );
            }));
            if flushed.is_err() {
                metrics.record_panic();
                log::warn!("batch flush for slot {key:?} panicked; requests dropped");
            }
        }
    }
}

/// Flush one same-slot group of requests: observations are absorbed
/// first (one batched `observe_batch` through the slot's
/// [`crate::online::OnlineObserver`]), then predictions run as a single
/// batched `predict_into` call into the worker's reusable buffers, and
/// the results fan back out to the per-request reply channels.
fn flush_group(
    key: &str,
    group: Vec<Pending>,
    registry: &ModelRegistry,
    metrics: &ServerMetrics,
    xt_data: &mut Vec<f64>,
    mean_buf: &mut Vec<f64>,
    var_buf: &mut Vec<f64>,
    wal: Option<&Durability>,
) {
    let model = match registry.get(Some(key)) {
        Some(m) => m,
        None => {
            // Slot removed between enqueue and flush.
            for p in group {
                let _ = p.reply.send(Err(anyhow::anyhow!("model slot {key:?} disappeared")));
            }
            metrics.record_error();
            return;
        }
    };
    let dim = model.dim();
    // A hot swap may have replaced the slot with a different-dimensional
    // model after enqueue validation: fail those requests individually,
    // keep the rest.
    let (group, stale): (Vec<Pending>, Vec<Pending>) =
        group.into_iter().partition(|p| p.dim == dim);
    for p in stale {
        metrics.record_error();
        let _ = p
            .reply
            .send(Err(anyhow::anyhow!("model slot {key:?} now expects {dim} dims")));
    }
    // Observations apply before this flush's predictions, so a client
    // that saw its observe acknowledged predicts against the updated
    // posterior from the next flush onward.
    let (observes, group): (Vec<Pending>, Vec<Pending>) =
        group.into_iter().partition(|p| p.kind == ReqKind::Observe);
    if !observes.is_empty() {
        flush_observes(key, model.as_ref(), observes, metrics, dim, wal);
    }
    if group.is_empty() {
        return;
    }

    // Tracing: each traced request owns its queue-wait span; the shared
    // flush work (assembly + predict, and whatever the model records
    // beneath predict) is billed to the first traced request's tree.
    for p in &group {
        if let Some(ctx) = &p.trace {
            let wait_us = p.enqueued.elapsed().as_micros() as u64;
            let now = ctx.tracer.now_us();
            ctx.record("queue-wait", now.saturating_sub(wait_us), wait_us);
        }
    }
    let _trace_guard = group.iter().find_map(|p| p.trace.clone()).map(trace::enter);

    let rows: usize = group.iter().map(|p| p.rows).sum();
    xt_data.clear();
    trace::span("batch-assembly", || {
        for p in &group {
            xt_data.extend_from_slice(&p.data);
        }
    });
    let xt = Matrix::from_vec(rows, dim, std::mem::take(xt_data));
    mean_buf.resize(rows, 0.0);
    var_buf.resize(rows, 0.0);
    let t0 = Instant::now();
    let result = trace::span("predict", || {
        // Inside the timed section, so an injected delay shows up in the
        // predict latency histogram (and hence the p99 SLO) like a real
        // slow flush would.
        crate::util::faults::hit("predict")?;
        model.predict_into(&xt, &mut mean_buf[..rows], &mut var_buf[..rows])
    });
    // Reclaim the matrix buffer for the next flush.
    *xt_data = xt.into_vec();

    match result {
        Ok(()) => {
            metrics.record_batch(rows, t0.elapsed().as_secs_f64());
            let mut at = 0;
            for p in group {
                let out: Vec<(f64, f64)> =
                    (at..at + p.rows).map(|i| (mean_buf[i], var_buf[i])).collect();
                at += p.rows;
                let _ = p.reply.send(Ok(out));
            }
        }
        Err(e) => {
            metrics.record_error();
            for p in group {
                let _ = p.reply.send(Err(anyhow::anyhow!("predict failed: {e:#}")));
            }
        }
    }
}

/// Absorb one same-slot group of observe requests, one `observe_batch`
/// call **per request** (each pending row is `dim` features followed by
/// the target). Per-request application costs nothing — the underlying
/// incremental updates are per-point anyway — and keeps the failure
/// blast radius honest: one client's bad batch cannot fail another
/// client's observations, and the observes counter only credits requests
/// whose absorption fully succeeded.
fn flush_observes(
    key: &str,
    model: &dyn Surrogate,
    group: Vec<Pending>,
    metrics: &ServerMetrics,
    dim: usize,
    wal: Option<&Durability>,
) {
    let observer = match model.observer() {
        Some(o) => o,
        None => {
            // A hot swap may have replaced an online slot with a
            // fit-once model after enqueue validation.
            for p in group {
                metrics.record_error();
                let _ = p.reply.send(Err(anyhow::anyhow!(
                    "model slot {key:?} is no longer online-capable"
                )));
            }
            return;
        }
    };
    for p in group {
        let mut xs = Vec::with_capacity(p.rows * dim);
        let mut ys = Vec::with_capacity(p.rows);
        for r in 0..p.rows {
            let row = &p.data[r * (dim + 1)..(r + 1) * (dim + 1)];
            xs.extend_from_slice(&row[..dim]);
            ys.push(row[dim]);
        }
        let xs = Matrix::from_vec(p.rows, dim, xs);
        let t0 = Instant::now();
        // Log-then-apply: with a WAL attached, the request's raw rows
        // are durable (per the fsync policy) before the model mutates,
        // and the lock held across both keeps checkpoints consistent.
        let applied = match wal {
            Some(d) => {
                d.append_then(key, p.rows, dim + 1, &p.data, || observer.observe_batch(&xs, &ys))
            }
            None => observer.observe_batch(&xs, &ys),
        };
        match applied {
            Ok(()) => {
                metrics.record_op(ProtocolOp::Observe, t0.elapsed().as_secs_f64());
                metrics.record_observes(p.rows);
                let _ = p.reply.send(Ok(Vec::new()));
            }
            Err(e) => {
                metrics.record_error();
                let _ = p.reply.send(Err(anyhow::anyhow!("observe failed: {e:#}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kriging::Prediction;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Test double: records batch sizes, returns x[0] as mean.
    struct Echo {
        dim: usize,
        calls: AtomicUsize,
        max_batch_seen: AtomicUsize,
    }

    impl Echo {
        fn new(dim: usize) -> Self {
            Self { dim, calls: AtomicUsize::new(0), max_batch_seen: AtomicUsize::new(0) }
        }
    }

    impl Surrogate for Echo {
        fn predict(&self, xt: &Matrix) -> anyhow::Result<Prediction> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            self.max_batch_seen.fetch_max(xt.rows(), Ordering::SeqCst);
            Ok(Prediction {
                mean: (0..xt.rows()).map(|i| xt[(i, 0)]).collect(),
                variance: vec![1.0; xt.rows()],
            })
        }

        fn name(&self) -> &str {
            "echo"
        }

        fn dim(&self) -> usize {
            self.dim
        }
    }

    fn registry_of(model: Arc<dyn Surrogate>) -> Arc<ModelRegistry> {
        Arc::new(ModelRegistry::new("default", model))
    }

    #[test]
    fn single_request_roundtrip() {
        let model = Arc::new(Echo::new(2));
        let b = Batcher::start(
            registry_of(model.clone()),
            BatcherConfig::default(),
            Arc::new(ServerMetrics::new()),
        );
        let (mean, var) = b.predict_one(&[3.5, 1.0]).unwrap();
        assert_eq!(mean, 3.5);
        assert_eq!(var, 1.0);
        drop(b);
        assert_eq!(model.calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let b = Batcher::start(
            registry_of(Arc::new(Echo::new(3))),
            BatcherConfig::default(),
            Arc::new(ServerMetrics::new()),
        );
        assert!(b.predict_one(&[1.0]).is_err());
        assert!(b.predict_rows(None, vec![1.0; 7], 2).is_err(), "7 values ≠ 2×3");
    }

    #[test]
    fn unknown_slot_rejected_at_enqueue() {
        let b = Batcher::start(
            registry_of(Arc::new(Echo::new(1))),
            BatcherConfig::default(),
            Arc::new(ServerMetrics::new()),
        );
        assert!(b.predict_one_for(Some("nope"), &[1.0]).is_err());
    }

    #[test]
    fn multi_point_request_roundtrip() {
        let b = Batcher::start(
            registry_of(Arc::new(Echo::new(2))),
            BatcherConfig::default(),
            Arc::new(ServerMetrics::new()),
        );
        let out = b.predict_rows(None, vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0], 3).unwrap();
        assert_eq!(out.iter().map(|p| p.0).collect::<Vec<_>>(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn traced_request_records_flush_spans() {
        use crate::obs::trace::{Sampling, TraceCtx, Tracer};
        let b = Batcher::start(
            registry_of(Arc::new(Echo::new(2))),
            BatcherConfig::default(),
            Arc::new(ServerMetrics::new()),
        );
        let tracer = Arc::new(Tracer::new(64, Sampling::Always));
        let trace_id = tracer.sample().unwrap();
        let root = tracer.next_id();
        let ctx = TraceCtx { tracer: Arc::clone(&tracer), trace_id, parent: root };
        b.predict_rows_traced(None, vec![1.0, 2.0], 1, Some(ctx)).unwrap();

        let spans = tracer.spans_for(trace_id);
        let names: Vec<&str> = spans.iter().map(|s| s.name.as_str()).collect();
        for want in ["queue-wait", "batch-assembly", "predict"] {
            assert!(names.contains(&want), "missing span {want:?} in {names:?}");
        }
        // Flush spans hang off the request's root, not off each other.
        assert!(spans.iter().all(|s| s.parent_id == root), "{spans:?}");

        // Untraced requests leave no spans behind.
        let before = spans.len();
        b.predict_one(&[0.0, 0.0]).unwrap();
        assert_eq!(tracer.spans_for(trace_id).len(), before);
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let model = Arc::new(Echo::new(1));
        let cfg = BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(20) };
        let metrics = Arc::new(ServerMetrics::new());
        let b = Arc::new(Batcher::start(registry_of(model.clone()), cfg, metrics.clone()));
        let mut handles = Vec::new();
        for i in 0..40 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || b.predict_one(&[i as f64]).unwrap()));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let (mean, _) = h.join().unwrap();
            assert_eq!(mean, i as f64);
        }
        // 40 concurrent requests should need far fewer than 40 predict
        // calls (batched), and at least one batch bigger than 1.
        let calls = model.calls.load(Ordering::SeqCst);
        assert!(calls < 40, "no batching happened ({calls} calls)");
        assert!(model.max_batch_seen.load(Ordering::SeqCst) > 1);
        assert!(metrics.predictions.load(Ordering::Relaxed) == 40);
    }

    #[test]
    fn named_slots_route_to_their_model() {
        let reg = registry_of(Arc::new(Echo::new(1)));
        struct Negate;
        impl Surrogate for Negate {
            fn predict(&self, xt: &Matrix) -> anyhow::Result<Prediction> {
                Ok(Prediction {
                    mean: (0..xt.rows()).map(|i| -xt[(i, 0)]).collect(),
                    variance: vec![0.0; xt.rows()],
                })
            }
            fn name(&self) -> &str {
                "negate"
            }
            fn dim(&self) -> usize {
                1
            }
        }
        reg.insert("neg", Arc::new(Negate));
        let b = Batcher::start(reg, BatcherConfig::default(), Arc::new(ServerMetrics::new()));
        assert_eq!(b.predict_one(&[2.0]).unwrap().0, 2.0);
        assert_eq!(b.predict_one_for(Some("neg"), &[2.0]).unwrap().0, -2.0);
    }

    /// Online-capable test double: tracks absorbed observations behind a
    /// mutex, predicts the running mean of the absorbed targets.
    struct ObservableEcho {
        dim: usize,
        absorbed: std::sync::Mutex<Vec<f64>>,
    }

    impl ObservableEcho {
        fn new(dim: usize) -> Self {
            Self { dim, absorbed: std::sync::Mutex::new(Vec::new()) }
        }
    }

    impl Surrogate for ObservableEcho {
        fn predict(&self, xt: &Matrix) -> anyhow::Result<Prediction> {
            let ys = self.absorbed.lock().unwrap();
            let mean = if ys.is_empty() { 0.0 } else { ys.iter().sum::<f64>() / ys.len() as f64 };
            Ok(Prediction { mean: vec![mean; xt.rows()], variance: vec![1.0; xt.rows()] })
        }
        fn name(&self) -> &str {
            "observable"
        }
        fn dim(&self) -> usize {
            self.dim
        }
        fn observer(&self) -> Option<&dyn crate::online::OnlineObserver> {
            Some(self)
        }
    }

    impl crate::online::OnlineObserver for ObservableEcho {
        fn observe_batch(&self, xs: &Matrix, ys: &[f64]) -> anyhow::Result<()> {
            anyhow::ensure!(xs.cols() == self.dim, "dim mismatch in double");
            self.absorbed.lock().unwrap().extend_from_slice(ys);
            Ok(())
        }
        fn online_stats(&self) -> crate::online::OnlineStats {
            crate::online::OnlineStats {
                observed: self.absorbed.lock().unwrap().len() as u64,
                ..Default::default()
            }
        }
    }

    #[test]
    fn observe_rows_roundtrip_and_metrics() {
        let model = Arc::new(ObservableEcho::new(2));
        let metrics = Arc::new(ServerMetrics::new());
        let b = Batcher::start(
            registry_of(model.clone()),
            BatcherConfig::default(),
            metrics.clone(),
        );
        // Two observations: rows are (x1, x2, y).
        b.observe_rows(None, vec![1.0, 2.0, 10.0, 3.0, 4.0, 20.0], 2).unwrap();
        assert_eq!(model.absorbed.lock().unwrap().as_slice(), &[10.0, 20.0]);
        assert_eq!(metrics.observes.load(Ordering::Relaxed), 2);
        // Predictions now reflect the absorbed targets.
        let (mean, _) = b.predict_one(&[0.0, 0.0]).unwrap();
        assert_eq!(mean, 15.0);
        assert_eq!(metrics.predictions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn observe_rows_validates_shape_and_capability() {
        let b = Batcher::start(
            registry_of(Arc::new(ObservableEcho::new(2))),
            BatcherConfig::default(),
            Arc::new(ServerMetrics::new()),
        );
        // Width must be dim+1 per row.
        assert!(b.observe_rows(None, vec![1.0, 2.0], 1).is_err());
        assert!(b.observe_rows(None, vec![1.0, 2.0, 3.0, 4.0], 1).is_err());
        // Unknown slot.
        assert!(b.observe_rows(Some("nope"), vec![1.0, 2.0, 3.0], 1).is_err());
        // Fit-once models reject observations at enqueue time.
        let plain = Batcher::start(
            registry_of(Arc::new(Echo::new(2))),
            BatcherConfig::default(),
            Arc::new(ServerMetrics::new()),
        );
        let err = plain.observe_rows(None, vec![1.0, 2.0, 3.0], 1).unwrap_err();
        assert!(err.to_string().contains("not online-capable"), "{err}");
    }

    #[test]
    fn mixed_observe_and_predict_flush() {
        let model = Arc::new(ObservableEcho::new(1));
        let cfg = BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(20) };
        let b = Arc::new(Batcher::start(
            registry_of(model.clone()),
            cfg,
            Arc::new(ServerMetrics::new()),
        ));
        let mut handles = Vec::new();
        for i in 0..10 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                if i % 2 == 0 {
                    b.observe_rows(None, vec![i as f64, i as f64], 1).unwrap();
                } else {
                    b.predict_one(&[i as f64]).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(model.absorbed.lock().unwrap().len(), 5);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let b = Batcher::start(
            registry_of(Arc::new(Echo::new(1))),
            BatcherConfig::default(),
            Arc::new(ServerMetrics::new()),
        );
        assert_eq!(b.depth(), 0);
        drop(b); // must not hang
    }

    /// Test double whose first predict panics; later calls succeed.
    struct PanicOnce {
        dim: usize,
        armed: std::sync::atomic::AtomicBool,
    }

    impl Surrogate for PanicOnce {
        fn predict(&self, xt: &Matrix) -> anyhow::Result<Prediction> {
            if self.armed.swap(false, Ordering::SeqCst) {
                panic!("injected model panic");
            }
            Ok(Prediction { mean: vec![0.0; xt.rows()], variance: vec![0.0; xt.rows()] })
        }
        fn name(&self) -> &str {
            "panic-once"
        }
        fn dim(&self) -> usize {
            self.dim
        }
    }

    #[test]
    fn model_panic_is_contained_and_counted() {
        let metrics = Arc::new(ServerMetrics::new());
        let b = Batcher::start(
            registry_of(Arc::new(PanicOnce {
                dim: 1,
                armed: std::sync::atomic::AtomicBool::new(true),
            })),
            BatcherConfig::default(),
            metrics.clone(),
        );
        let err = b.predict_one(&[1.0]).unwrap_err();
        assert!(err.to_string().contains("dropped"), "{err}");
        assert_eq!(metrics.panics.load(Ordering::Relaxed), 1);
        // The worker thread survived the panic and keeps serving.
        assert!(b.predict_one(&[2.0]).is_ok());
    }

    #[test]
    fn wal_attached_observes_are_logged_before_apply() {
        use crate::online::wal::{recover, Durability, DurabilityConfig, FsyncPolicy};
        let dir = std::env::temp_dir().join(format!("ckrig_batwal_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = DurabilityConfig {
            dir: dir.clone(),
            fsync: FsyncPolicy::Always,
            checkpoint_every: 0,
        };
        let rec = recover(&dir, cfg.fsync).unwrap();
        let dur = Durability::new(rec.wal, &cfg);
        let model = Arc::new(ObservableEcho::new(2));
        let b = Batcher::start_with_wal(
            registry_of(model.clone()),
            BatcherConfig::default(),
            Arc::new(ServerMetrics::new()),
            Some(Arc::clone(&dur)),
        );
        b.observe_rows(None, vec![1.0, 2.0, 10.0, 3.0, 4.0, 20.0], 2).unwrap();
        b.observe_rows(None, vec![5.0, 6.0, 30.0], 1).unwrap();
        assert_eq!(model.absorbed.lock().unwrap().len(), 3);
        assert_eq!(dur.last_seq(), 2, "one wal record per observe request");
        drop(b);
        drop(dur);
        // Everything acknowledged is on disk.
        let rec = recover(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(rec.replay.len(), 2);
        assert_eq!(rec.replay[0].model, "default");
        assert_eq!(rec.replay[0].rows, 2);
        assert_eq!(rec.replay[0].data, vec![1.0, 2.0, 10.0, 3.0, 4.0, 20.0]);
        assert_eq!(rec.replay[1].data, vec![5.0, 6.0, 30.0]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
