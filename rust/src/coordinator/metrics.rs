//! Serving metrics: counters and latency histograms for the coordinator.
//!
//! Latencies are tracked twice: one aggregate histogram (the historical
//! `lat_*` summary keys, kept stable for dashboards and tests) and one
//! histogram **per protocol op** ([`ProtocolOp`]) — predict, observe,
//! suggest and the distributed `spredict` each get their own buckets, so
//! shard fan-out cost is attributable in `stats` instead of being
//! averaged into the predict latency it inflates.
//!
//! Everything here is lock-free ([`AtomicHistogram`] buckets and
//! `AtomicU64` counters): `record_op` on the predict path used to
//! serialize every connection thread through two mutex acquisitions per
//! op, and `summary()` took the aggregate lock three more times per
//! render. Now a record is a handful of relaxed atomic adds and a
//! reader can scrape mid-flight without stalling a single request.

use crate::obs::hist::{AtomicHistogram, HistogramSnapshot};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Protocol op families with separately tracked latency histograms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProtocolOp {
    /// `predict`/`predictb` flush execution (one batched `predict_into`).
    Predict,
    /// `observe`/`observeb`/`tell` absorption.
    Observe,
    /// `suggest` proposal (acquisition maximization over the posterior).
    Suggest,
    /// `spredict` raw per-cluster prediction (the shard-worker side of
    /// the scatter-gather path, protocol v5).
    ShardPredict,
}

impl ProtocolOp {
    const COUNT: usize = 4;

    fn index(self) -> usize {
        match self {
            ProtocolOp::Predict => 0,
            ProtocolOp::Observe => 1,
            ProtocolOp::Suggest => 2,
            ProtocolOp::ShardPredict => 3,
        }
    }

    /// Stable key used in `stats` summaries and `metricsx` labels.
    pub fn key(self) -> &'static str {
        match self {
            ProtocolOp::Predict => "predict",
            ProtocolOp::Observe => "observe",
            ProtocolOp::Suggest => "suggest",
            ProtocolOp::ShardPredict => "spredict",
        }
    }

    /// Every tracked op, in summary order.
    pub const ALL: [ProtocolOp; Self::COUNT] = [
        ProtocolOp::Predict,
        ProtocolOp::Observe,
        ProtocolOp::Suggest,
        ProtocolOp::ShardPredict,
    ];
}

/// Lock-free counters + lock-free bucket histograms, plus the process
/// identity gauges (`uptime_s`, `started_unix`, build version) that
/// fleet dashboards use to spot restarts and version skew.
#[derive(Debug)]
pub struct ServerMetrics {
    pub requests: AtomicU64,
    pub predictions: AtomicU64,
    /// Observations absorbed through the `observe`/`observeb`/`tell`
    /// protocol ops (protocol v3/v4 — the online-learning path).
    pub observes: AtomicU64,
    /// Candidate points proposed through the `suggest` protocol op
    /// (protocol v4 — the optimization-as-a-service path).
    pub suggests: AtomicU64,
    /// Raw per-cluster prediction rows served through `spredict`
    /// (protocol v5 — this process answering as a shard worker).
    pub spredicts: AtomicU64,
    /// Scatter-gather merges that had to drop ≥ 1 dead or timed-out
    /// shard and renormalize over the survivors (protocol v5 — this
    /// process acting as a shard coordinator).
    pub degraded: AtomicU64,
    /// Idempotent sub-requests re-issued against a freshly reconnected
    /// shard after a transport failure (robustness layer).
    pub retries: AtomicU64,
    /// Request handlers or background workers that panicked and were
    /// contained by `catch_unwind` instead of taking down the process.
    pub panics: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    latencies: AtomicHistogram,
    per_op: [AtomicHistogram; ProtocolOp::COUNT],
    started: Instant,
    started_unix: u64,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self {
            requests: AtomicU64::new(0),
            predictions: AtomicU64::new(0),
            observes: AtomicU64::new(0),
            suggests: AtomicU64::new(0),
            spredicts: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            latencies: AtomicHistogram::new(),
            per_op: Default::default(),
            started: Instant::now(),
            started_unix: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
        }
    }

    /// Seconds since this metrics object (≈ the server) was created.
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Wall-clock boot time (seconds since the Unix epoch).
    pub fn started_unix(&self) -> u64 {
        self.started_unix
    }

    /// Crate version baked into the binary, for version-skew dashboards.
    pub fn version() -> &'static str {
        env!("CARGO_PKG_VERSION")
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `count` observations absorbed by a served model.
    pub fn record_observes(&self, count: usize) {
        self.observes.fetch_add(count as u64, Ordering::Relaxed);
    }

    /// Record `count` candidate points proposed by a `suggest` op.
    pub fn record_suggests(&self, count: usize) {
        self.suggests.fetch_add(count as u64, Ordering::Relaxed);
    }

    /// Record `count` rows answered with raw per-cluster posteriors by an
    /// `spredict` op.
    pub fn record_spredicts(&self, count: usize) {
        self.spredicts.fetch_add(count as u64, Ordering::Relaxed);
    }

    /// Record one scatter-gather merge that dropped ≥ 1 shard.
    pub fn record_degraded(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one retried sub-request (after a transport failure).
    pub fn record_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one contained panic.
    pub fn record_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one op execution of `seconds` into that op's latency
    /// histogram **and** the aggregate histogram. Lock-free.
    pub fn record_op(&self, op: ProtocolOp, seconds: f64) {
        let us = (seconds * 1e6) as u64;
        self.latencies.record_us(us);
        self.per_op[op.index()].record_us(us);
    }

    /// Record one served batch of `size` predictions taking `seconds`.
    pub fn record_batch(&self, size: usize, seconds: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.predictions.fetch_add(size as u64, Ordering::Relaxed);
        self.record_op(ProtocolOp::Predict, seconds);
    }

    /// Approximate latency percentile from the aggregate histogram (µs).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        self.latencies.percentile_us(p)
    }

    /// Approximate latency percentile for one protocol op (µs).
    pub fn op_percentile_us(&self, op: ProtocolOp, p: f64) -> u64 {
        self.per_op[op.index()].percentile_us(p)
    }

    /// Samples recorded for one protocol op.
    pub fn op_count(&self, op: ProtocolOp) -> u64 {
        self.per_op[op.index()].count()
    }

    /// Bucket snapshot of the aggregate latency histogram (exposition).
    pub fn latency_snapshot(&self) -> HistogramSnapshot {
        self.latencies.snapshot()
    }

    /// Bucket snapshot of one op's latency histogram (exposition).
    pub fn op_snapshot(&self, op: ProtocolOp) -> HistogramSnapshot {
        self.per_op[op.index()].snapshot()
    }

    pub fn mean_latency_us(&self) -> f64 {
        self.latencies.mean_us()
    }

    /// One-line human-readable summary. The historical aggregate keys
    /// come first; per-op percentiles follow, one `<op>_p50/p99` pair per
    /// op that has recorded at least one sample.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} predictions={} observes={} suggests={} spredicts={} \
             degraded={} retries={} panics={} batches={} errors={} \
             lat_mean={:.0}µs lat_p50={}µs lat_p99={}µs",
            self.requests.load(Ordering::Relaxed),
            self.predictions.load(Ordering::Relaxed),
            self.observes.load(Ordering::Relaxed),
            self.suggests.load(Ordering::Relaxed),
            self.spredicts.load(Ordering::Relaxed),
            self.degraded.load(Ordering::Relaxed),
            self.retries.load(Ordering::Relaxed),
            self.panics.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.mean_latency_us(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(99.0),
        );
        for op in ProtocolOp::ALL {
            let h = &self.per_op[op.index()];
            if h.count() > 0 {
                s.push_str(&format!(
                    " {key}_p50={}µs {key}_p99={}µs",
                    h.percentile_us(50.0),
                    h.percentile_us(99.0),
                    key = op.key()
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::BUCKET_BOUNDS_US;

    #[test]
    fn counters_accumulate() {
        let m = ServerMetrics::new();
        m.record_request();
        m.record_request();
        m.record_error();
        m.record_batch(8, 0.001);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.errors.load(Ordering::Relaxed), 1);
        assert_eq!(m.predictions.load(Ordering::Relaxed), 8);
        assert_eq!(m.batches.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn percentiles_reflect_distribution() {
        let m = ServerMetrics::new();
        for _ in 0..99 {
            m.record_batch(1, 50e-6); // 50µs → bucket 100
        }
        m.record_batch(1, 0.5); // 500ms → bucket 1s
        assert_eq!(m.latency_percentile_us(50.0), 100);
        assert!(m.latency_percentile_us(99.9) >= 300_000);
        assert!(m.mean_latency_us() > 50.0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = ServerMetrics::new();
        assert_eq!(m.latency_percentile_us(99.0), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert!(m.summary().contains("requests=0"));
        assert!(m.summary().contains("observes=0"));
        assert!(m.summary().contains("degraded=0"));
        for op in ProtocolOp::ALL {
            assert_eq!(m.op_percentile_us(op, 99.0), 0);
            assert_eq!(m.op_count(op), 0);
        }
    }

    #[test]
    fn observes_counter_accumulates() {
        let m = ServerMetrics::new();
        m.record_observes(3);
        m.record_observes(1);
        assert_eq!(m.observes.load(Ordering::Relaxed), 4);
        assert!(m.summary().contains("observes=4"));
        // Observations are not predictions.
        assert_eq!(m.predictions.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn suggests_counter_accumulates() {
        let m = ServerMetrics::new();
        m.record_suggests(4);
        m.record_suggests(1);
        assert_eq!(m.suggests.load(Ordering::Relaxed), 5);
        assert!(m.summary().contains("suggests=5"));
        // Proposals are neither predictions nor observations.
        assert_eq!(m.predictions.load(Ordering::Relaxed), 0);
        assert_eq!(m.observes.load(Ordering::Relaxed), 0);
        assert!(ServerMetrics::new().summary().contains("suggests=0"));
    }

    #[test]
    fn spredict_and_degraded_counters_accumulate() {
        let m = ServerMetrics::new();
        m.record_spredicts(16);
        m.record_spredicts(4);
        m.record_degraded();
        assert_eq!(m.spredicts.load(Ordering::Relaxed), 20);
        assert_eq!(m.degraded.load(Ordering::Relaxed), 1);
        let s = m.summary();
        assert!(s.contains("spredicts=20"), "{s}");
        assert!(s.contains("degraded=1"), "{s}");
        // Shard rows are neither predictions nor observations.
        assert_eq!(m.predictions.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn retry_and_panic_counters_accumulate() {
        let m = ServerMetrics::new();
        m.record_retry();
        m.record_retry();
        m.record_panic();
        assert_eq!(m.retries.load(Ordering::Relaxed), 2);
        assert_eq!(m.panics.load(Ordering::Relaxed), 1);
        let s = m.summary();
        assert!(s.contains("retries=2"), "{s}");
        assert!(s.contains("panics=1"), "{s}");
    }

    #[test]
    fn per_op_histograms_are_independent() {
        let m = ServerMetrics::new();
        // Slow observes must not inflate the predict percentiles: the
        // whole point of splitting the buckets by op.
        for _ in 0..10 {
            m.record_op(ProtocolOp::Predict, 50e-6); // 50µs → bucket 100
        }
        for _ in 0..10 {
            m.record_op(ProtocolOp::Observe, 0.02); // 20ms → bucket 30ms
        }
        m.record_op(ProtocolOp::ShardPredict, 2e-3);
        assert_eq!(m.op_percentile_us(ProtocolOp::Predict, 99.0), 100);
        assert_eq!(m.op_percentile_us(ProtocolOp::Observe, 99.0), 30_000);
        assert_eq!(m.op_percentile_us(ProtocolOp::ShardPredict, 99.0), 3_000);
        assert_eq!(m.op_count(ProtocolOp::Suggest), 0);
        // The aggregate histogram still sees everything.
        assert!(m.latency_percentile_us(99.0) >= 30_000);
        // Only ops with samples appear in the summary.
        let s = m.summary();
        assert!(s.contains("predict_p50=100µs"), "{s}");
        assert!(s.contains("observe_p99=30000µs"), "{s}");
        assert!(s.contains("spredict_p50=3000µs"), "{s}");
        assert!(!s.contains("suggest_p50"), "{s}");
    }

    #[test]
    fn record_batch_feeds_the_predict_histogram() {
        let m = ServerMetrics::new();
        m.record_batch(4, 50e-6);
        assert_eq!(m.op_count(ProtocolOp::Predict), 1);
        assert_eq!(m.op_percentile_us(ProtocolOp::Predict, 100.0), 100);
    }

    #[test]
    fn histogram_bucket_boundaries_are_inclusive_upper() {
        // A latency exactly on a bucket bound must land IN that bucket
        // (`us <= bound`), not the next one: recording exactly `bound` µs
        // and asking for p100 must report that bound back.
        for &bound in &BUCKET_BOUNDS_US {
            let m = ServerMetrics::new();
            m.record_batch(1, bound as f64 * 1e-6);
            assert_eq!(
                m.latency_percentile_us(100.0),
                bound,
                "latency of exactly {bound}µs fell outside its bucket"
            );
        }
        // Past a bound the count spills into the next bucket (2·bound is
        // always within the next bucket for this 1–3–10 spacing, and far
        // enough from both edges to survive the f64 µs round-trip).
        for w in BUCKET_BOUNDS_US.windows(2) {
            let m = ServerMetrics::new();
            m.record_batch(1, (w[0] * 2) as f64 * 1e-6);
            assert_eq!(
                m.latency_percentile_us(100.0),
                w[1],
                "latency of {}µs did not spill into the {}µs bucket",
                w[0] * 2,
                w[1]
            );
        }
    }

    #[test]
    fn overflow_bucket_reports_observed_max() {
        // Beyond the last bound the histogram is unbounded; percentiles
        // falling there report the true observed maximum.
        let m = ServerMetrics::new();
        let last = *BUCKET_BOUNDS_US.last().unwrap();
        m.record_batch(1, (last + 500_000) as f64 * 1e-6);
        assert_eq!(m.latency_percentile_us(100.0), last + 500_000);
    }

    #[test]
    fn zero_latency_lands_in_first_bucket() {
        let m = ServerMetrics::new();
        m.record_batch(1, 0.0);
        assert_eq!(m.latency_percentile_us(100.0), BUCKET_BOUNDS_US[0]);
    }

    #[test]
    fn identity_gauges_are_present() {
        let m = ServerMetrics::new();
        assert!(m.uptime_s() >= 0.0);
        assert!(m.started_unix() > 1_500_000_000, "boot time predates the crate");
        assert!(!ServerMetrics::version().is_empty());
    }

    #[test]
    fn recording_under_concurrency_loses_nothing() {
        use std::sync::Arc;
        // The lock-free rewrite's contract: concurrent record_op calls
        // from many connection threads all land.
        let m = Arc::new(ServerMetrics::new());
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        m.record_op(ProtocolOp::Predict, 50e-6);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(m.op_count(ProtocolOp::Predict), 4000);
        assert_eq!(m.latency_snapshot().n, 4000);
    }
}
