//! Serving metrics: counters and latency histograms for the coordinator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Fixed logarithmic latency buckets (µs).
const BUCKET_BOUNDS_US: [u64; 12] =
    [10, 30, 100, 300, 1_000, 3_000, 10_000, 30_000, 100_000, 300_000, 1_000_000, 3_000_000];

/// Lock-free counters + a mutex-guarded histogram.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub requests: AtomicU64,
    pub predictions: AtomicU64,
    /// Observations absorbed through the `observe`/`observeb`/`tell`
    /// protocol ops (protocol v3/v4 — the online-learning path).
    pub observes: AtomicU64,
    /// Candidate points proposed through the `suggest` protocol op
    /// (protocol v4 — the optimization-as-a-service path).
    pub suggests: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    latencies: Mutex<Histogram>,
}

#[derive(Debug, Default)]
struct Histogram {
    counts: [u64; BUCKET_BOUNDS_US.len() + 1],
    total_us: u64,
    n: u64,
    max_us: u64,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `count` observations absorbed by a served model.
    pub fn record_observes(&self, count: usize) {
        self.observes.fetch_add(count as u64, Ordering::Relaxed);
    }

    /// Record `count` candidate points proposed by a `suggest` op.
    pub fn record_suggests(&self, count: usize) {
        self.suggests.fetch_add(count as u64, Ordering::Relaxed);
    }

    /// Record one served batch of `size` predictions taking `seconds`.
    pub fn record_batch(&self, size: usize, seconds: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.predictions.fetch_add(size as u64, Ordering::Relaxed);
        let us = (seconds * 1e6) as u64;
        let mut h = self.latencies.lock().unwrap();
        let idx = BUCKET_BOUNDS_US.iter().position(|&b| us <= b).unwrap_or(BUCKET_BOUNDS_US.len());
        h.counts[idx] += 1;
        h.total_us += us;
        h.n += 1;
        h.max_us = h.max_us.max(us);
    }

    /// Approximate latency percentile from the histogram (µs).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let h = self.latencies.lock().unwrap();
        if h.n == 0 {
            return 0;
        }
        let target = ((p / 100.0) * h.n as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in h.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i < BUCKET_BOUNDS_US.len() { BUCKET_BOUNDS_US[i] } else { h.max_us };
            }
        }
        h.max_us
    }

    pub fn mean_latency_us(&self) -> f64 {
        let h = self.latencies.lock().unwrap();
        if h.n == 0 {
            0.0
        } else {
            h.total_us as f64 / h.n as f64
        }
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "requests={} predictions={} observes={} suggests={} batches={} errors={} \
             lat_mean={:.0}µs lat_p50={}µs lat_p99={}µs",
            self.requests.load(Ordering::Relaxed),
            self.predictions.load(Ordering::Relaxed),
            self.observes.load(Ordering::Relaxed),
            self.suggests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.mean_latency_us(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(99.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = ServerMetrics::new();
        m.record_request();
        m.record_request();
        m.record_error();
        m.record_batch(8, 0.001);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.errors.load(Ordering::Relaxed), 1);
        assert_eq!(m.predictions.load(Ordering::Relaxed), 8);
        assert_eq!(m.batches.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn percentiles_reflect_distribution() {
        let m = ServerMetrics::new();
        for _ in 0..99 {
            m.record_batch(1, 50e-6); // 50µs → bucket 100
        }
        m.record_batch(1, 0.5); // 500ms → bucket 1s
        assert_eq!(m.latency_percentile_us(50.0), 100);
        assert!(m.latency_percentile_us(99.9) >= 300_000);
        assert!(m.mean_latency_us() > 50.0);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = ServerMetrics::new();
        assert_eq!(m.latency_percentile_us(99.0), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert!(m.summary().contains("requests=0"));
        assert!(m.summary().contains("observes=0"));
    }

    #[test]
    fn observes_counter_accumulates() {
        let m = ServerMetrics::new();
        m.record_observes(3);
        m.record_observes(1);
        assert_eq!(m.observes.load(Ordering::Relaxed), 4);
        assert!(m.summary().contains("observes=4"));
        // Observations are not predictions.
        assert_eq!(m.predictions.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn suggests_counter_accumulates() {
        let m = ServerMetrics::new();
        m.record_suggests(4);
        m.record_suggests(1);
        assert_eq!(m.suggests.load(Ordering::Relaxed), 5);
        assert!(m.summary().contains("suggests=5"));
        // Proposals are neither predictions nor observations.
        assert_eq!(m.predictions.load(Ordering::Relaxed), 0);
        assert_eq!(m.observes.load(Ordering::Relaxed), 0);
        assert!(ServerMetrics::new().summary().contains("suggests=0"));
    }

    #[test]
    fn histogram_bucket_boundaries_are_inclusive_upper() {
        // A latency exactly on a bucket bound must land IN that bucket
        // (`us <= bound`), not the next one: recording exactly `bound` µs
        // and asking for p100 must report that bound back.
        for &bound in &BUCKET_BOUNDS_US {
            let m = ServerMetrics::new();
            m.record_batch(1, bound as f64 * 1e-6);
            assert_eq!(
                m.latency_percentile_us(100.0),
                bound,
                "latency of exactly {bound}µs fell outside its bucket"
            );
        }
        // Past a bound the count spills into the next bucket (2·bound is
        // always within the next bucket for this 1–3–10 spacing, and far
        // enough from both edges to survive the f64 µs round-trip).
        for w in BUCKET_BOUNDS_US.windows(2) {
            let m = ServerMetrics::new();
            m.record_batch(1, (w[0] * 2) as f64 * 1e-6);
            assert_eq!(
                m.latency_percentile_us(100.0),
                w[1],
                "latency of {}µs did not spill into the {}µs bucket",
                w[0] * 2,
                w[1]
            );
        }
    }

    #[test]
    fn overflow_bucket_reports_observed_max() {
        // Beyond the last bound the histogram is unbounded; percentiles
        // falling there report the true observed maximum.
        let m = ServerMetrics::new();
        let last = *BUCKET_BOUNDS_US.last().unwrap();
        m.record_batch(1, (last + 500_000) as f64 * 1e-6);
        assert_eq!(m.latency_percentile_us(100.0), last + 500_000);
    }

    #[test]
    fn zero_latency_lands_in_first_bucket() {
        let m = ServerMetrics::new();
        m.record_batch(1, 0.0);
        assert_eq!(m.latency_percentile_us(100.0), BUCKET_BOUNDS_US[0]);
    }
}
