//! Ordinary Kriging — paper §II, Eq. 3–5.
//!
//! Parameterization: the covariance is `σ²·(R + λI)` where `R` is the unit
//! diagonal correlation matrix from [`Kernel`], `λ = σ_γ²/σ²` the *relative
//! nugget* and `σ²` the process variance. `σ²` and the constant trend `μ`
//! are concentrated out by their closed-form ML/MAP estimates, so the
//! hyper-parameter search only runs over `θ` (and optionally `λ`).
//!
//! Posterior mean (Eq. 4):  m(x)  = μ̂ + r(x)ᵀ C⁻¹ (y − μ̂·1)
//! Posterior var  (Eq. 5):  s²(x) = σ̂²·[λ + 1 − r(x)ᵀC⁻¹r(x)
//!                                    + (1 − 1ᵀC⁻¹r(x))²/(1ᵀC⁻¹1)]
//! with C = R + λI and r(x) the correlation vector to the training set.

use crate::kernel::cache::DistanceCache;
use crate::kernel::Kernel;
use crate::linalg::{Cholesky, CholeskyError};
use crate::obs::health::ModelHealth;
use crate::obs::trace;
use crate::util::matrix::Matrix;
use crate::util::threadpool::default_workers;
use std::sync::Arc;
use thiserror::Error;

#[derive(Debug, Error)]
pub enum KrigingError {
    #[error("training set is empty")]
    EmptyTrainingSet,
    #[error("dimension mismatch: x has {x_cols} cols, kernel expects {kernel_dim}")]
    DimMismatch { x_cols: usize, kernel_dim: usize },
    #[error("x has {x_rows} rows but y has {y_len} values")]
    RowMismatch { x_rows: usize, y_len: usize },
    #[error("distance cache incompatible with fit inputs: {reason}")]
    CacheMismatch { reason: &'static str },
    #[error("correlation matrix factorization failed: {0}")]
    Factorization(#[from] CholeskyError),
    #[error("non-finite value encountered in {0}")]
    NonFinite(&'static str),
}

/// Joint mean/variance prediction for a batch of points.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub mean: Vec<f64>,
    pub variance: Vec<f64>,
}

impl Prediction {
    pub fn len(&self) -> usize {
        self.mean.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mean.is_empty()
    }
}

/// A fitted Ordinary Kriging model.
#[derive(Debug, Clone)]
pub struct OrdinaryKriging {
    kernel: Kernel,
    /// Relative nugget λ = σ_γ² / σ².
    nugget: f64,
    /// Training inputs, shared (`Arc`) so the hyperopt loop's ~180 fits
    /// per cluster reference one buffer instead of cloning n×d doubles
    /// per objective evaluation.
    x: Arc<Matrix>,
    /// Training targets — kept so online updates ([`Self::observe_point`])
    /// can re-concentrate μ̂/σ̂²/α after the factor grows, and so refits
    /// can snapshot the effective training set.
    y: Vec<f64>,
    chol: Cholesky,
    /// α = C⁻¹(y − μ̂·1): the prediction weights.
    alpha: Vec<f64>,
    /// 1ᵀC⁻¹1.
    one_c_one: f64,
    mu_hat: f64,
    /// σ̂²: ML estimate of the process variance.
    sigma2: f64,
    /// Concentrated negative log-likelihood of (θ, λ) on this data.
    nll: f64,
    /// Cached numerical-health probe (condition estimate + jitter).
    /// `Some` after a fit-time probe; invalidated to `None` by every
    /// online update so a stale estimate is never served.
    health: Option<ModelHealth>,
}

impl OrdinaryKriging {
    /// Fit on inputs `x` (n×d) and outputs `y` (n) with the given kernel
    /// and relative nugget λ ≥ 0.
    pub fn fit(x: Matrix, y: &[f64], kernel: Kernel, nugget: f64) -> Result<Self, KrigingError> {
        Self::fit_shared(Arc::new(x), y, kernel, nugget)
    }

    /// [`Self::fit`] over a shared training matrix — no copy is taken;
    /// the model keeps a reference-counted handle.
    pub fn fit_shared(
        x: Arc<Matrix>,
        y: &[f64],
        kernel: Kernel,
        nugget: f64,
    ) -> Result<Self, KrigingError> {
        let mut m = Self::fit_shared_with_workers(x, y, kernel, nugget, default_workers())?;
        // Probe here, not in fit_core: the hyperopt objective funnels
        // through fit_with_cache/fit_shared_with_workers hundreds of
        // times per cluster and must not pay the probe per evaluation.
        m.probe_health();
        Ok(m)
    }

    /// [`Self::fit_shared`] with an explicit worker budget for the
    /// factorization. Pass 1 from already-parallel contexts (per-cluster
    /// or per-module fits) so nested factorizations don't oversubscribe
    /// the machine; the fitted model is identical for any worker count.
    pub fn fit_shared_with_workers(
        x: Arc<Matrix>,
        y: &[f64],
        kernel: Kernel,
        nugget: f64,
        workers: usize,
    ) -> Result<Self, KrigingError> {
        Self::validate(&x, y, &kernel)?;
        let workers = workers.max(1);
        // C = R + λI. corr_matrix_parallel computes the same scalar corr
        // per element, so the matrix is bit-identical for any worker count.
        let mut c = kernel.corr_matrix_parallel(&x, workers);
        for i in 0..x.rows() {
            c[(i, i)] += nugget;
        }
        Self::fit_core(x, y, kernel, nugget, c, workers)
    }

    /// Fit with the correlation matrix assembled from a precomputed
    /// [`DistanceCache`] instead of a scalar O(n²d) pass — the hyperopt
    /// hot path, where only θ changes between calls. Produces bit-
    /// identical results to [`Self::fit`] (the cache reproduces the
    /// scalar accumulation order exactly).
    pub fn fit_with_cache(
        x: Arc<Matrix>,
        y: &[f64],
        kernel: Kernel,
        nugget: f64,
        cache: &DistanceCache,
        workers: usize,
    ) -> Result<Self, KrigingError> {
        Self::validate(&x, y, &kernel)?;
        // Pre-check every cache precondition here so API misuse is a
        // recoverable error, not a panic from the cache's own asserts.
        if cache.len() != x.rows() {
            return Err(KrigingError::CacheMismatch {
                reason: "cache built for a different number of points",
            });
        }
        if cache.dim() != kernel.dim() {
            return Err(KrigingError::CacheMismatch {
                reason: "cache built for a different input dimension",
            });
        }
        if cache.squared() != kernel.kind.uses_squared_distance() {
            return Err(KrigingError::CacheMismatch {
                reason: "cache metric (squared vs L1) does not match the kernel family",
            });
        }
        let mut c = cache.corr_matrix(&kernel, workers.max(1));
        for i in 0..x.rows() {
            c[(i, i)] += nugget;
        }
        Self::fit_core(x, y, kernel, nugget, c, workers.max(1))
    }

    fn validate(x: &Matrix, y: &[f64], kernel: &Kernel) -> Result<(), KrigingError> {
        let n = x.rows();
        if n == 0 {
            return Err(KrigingError::EmptyTrainingSet);
        }
        if x.cols() != kernel.dim() {
            return Err(KrigingError::DimMismatch { x_cols: x.cols(), kernel_dim: kernel.dim() });
        }
        if y.len() != n {
            return Err(KrigingError::RowMismatch { x_rows: n, y_len: y.len() });
        }
        if y.iter().any(|v| !v.is_finite()) {
            crate::obs::health::counters().note_nonfinite();
            return Err(KrigingError::NonFinite("y"));
        }
        Ok(())
    }

    /// Shared fit tail: factor `C = R + λI` and concentrate out μ̂/σ̂².
    fn fit_core(
        x: Arc<Matrix>,
        y: &[f64],
        kernel: Kernel,
        nugget: f64,
        c: Matrix,
        workers: usize,
    ) -> Result<Self, KrigingError> {
        let chol = Cholesky::new_regularized_with_workers(&c, workers)?;
        let (alpha, one_c_one, mu_hat, sigma2, nll) = concentrate(&chol, y)?;
        Ok(Self {
            kernel,
            nugget,
            x,
            y: y.to_vec(),
            chol,
            alpha,
            one_c_one,
            mu_hat,
            sigma2,
            nll,
            health: None,
        })
    }

    /// Absorb one observation under **fixed hyper-parameters**: extend the
    /// Cholesky factor by one row ([`Cholesky::append`], O(n²)) and
    /// re-concentrate μ̂/σ̂²/α with two triangular solves — instead of the
    /// O(n³) refit a fresh point would otherwise cost. Predictions after
    /// `observe_point` match a from-scratch fit on the extended training
    /// set (same θ/λ) to rounding error.
    ///
    /// If the incremental append hits a non-PD pivot (the new point
    /// coincides with an existing one and the nugget can't separate them),
    /// the update falls back to a full jitter-escalating refactorization,
    /// mirroring [`Cholesky::new_regularized`] at fit time.
    ///
    /// The update is atomic: every fallible step runs on candidate state,
    /// and `self` is only committed on success — an `Err` leaves the
    /// model exactly as it was, still serving consistent predictions.
    pub fn observe_point(&mut self, x_new: &[f64], y_new: f64) -> Result<(), KrigingError> {
        self.validate_observation(x_new, y_new)?;
        let n = self.x.rows();
        let mut r = Vec::with_capacity(n);
        for j in 0..n {
            r.push(self.kernel.corr(x_new, self.x.row(j)));
        }
        let x_aug = append_row(&self.x, x_new);
        let mut y_aug = self.y.clone();
        y_aug.push(y_new);
        let chol = match self.chol.appended(&r, 1.0 + self.nugget) {
            Ok(c) => c,
            Err(_) => {
                let full = factor_full(&self.kernel, &x_aug, self.nugget)?;
                note_factor_fallback("observe_point", x_aug.rows(), full.jitter());
                full
            }
        };
        self.commit(x_aug, y_aug, chol)
    }

    /// Replace training point `i` with a new observation — the reservoir-
    /// sampling / sliding-window eviction op: O(n²) via
    /// [`Cholesky::removed_row`] + [`Cholesky::appended`], with the same
    /// full-refactorization fallback and commit-on-success atomicity as
    /// [`Self::observe_point`].
    pub fn replace_point(
        &mut self,
        i: usize,
        x_new: &[f64],
        y_new: f64,
    ) -> Result<(), KrigingError> {
        let n = self.x.rows();
        assert!(i < n, "replace_point: index {i} out of range for {n} training points");
        self.validate_observation(x_new, y_new)?;
        if n == 1 {
            // Cannot empty the factor; rebuild the 1-point model directly.
            let x_aug = Matrix::from_vec(1, x_new.len(), x_new.to_vec());
            let chol = factor_full(&self.kernel, &x_aug, self.nugget)?;
            return self.commit(x_aug, vec![y_new], chol);
        }
        let keep: Vec<usize> = (0..n).filter(|&j| j != i).collect();
        let x_kept = self.x.select_rows(&keep);
        let mut y_aug: Vec<f64> = keep.iter().map(|&j| self.y[j]).collect();
        y_aug.push(y_new);
        let m = x_kept.rows();
        let mut r = Vec::with_capacity(m);
        for j in 0..m {
            r.push(self.kernel.corr(x_new, x_kept.row(j)));
        }
        let x_aug = append_row(&x_kept, x_new);
        let shrunk = self.chol.removed_row(i);
        let chol = match shrunk.appended(&r, 1.0 + self.nugget) {
            Ok(c) => c,
            Err(_) => {
                let full = factor_full(&self.kernel, &x_aug, self.nugget)?;
                note_factor_fallback("replace_point", x_aug.rows(), full.jitter());
                full
            }
        };
        self.commit(x_aug, y_aug, chol)
    }

    /// Drop training point `i` with no replacement — the pure eviction
    /// half of sliding-window forgetting: O(n²) via
    /// [`Cholesky::removed_row`] with the same commit-on-success
    /// atomicity as the other online ops. A model cannot forget its last
    /// point (`EmptyTrainingSet`), so bounded windows stay ≥ 1.
    pub fn forget_point(&mut self, i: usize) -> Result<(), KrigingError> {
        let n = self.x.rows();
        assert!(i < n, "forget_point: index {i} out of range for {n} training points");
        if n == 1 {
            return Err(KrigingError::EmptyTrainingSet);
        }
        let keep: Vec<usize> = (0..n).filter(|&j| j != i).collect();
        let x_kept = self.x.select_rows(&keep);
        let y_kept: Vec<f64> = keep.iter().map(|&j| self.y[j]).collect();
        let chol = self.chol.removed_row(i);
        self.commit(x_kept, y_kept, chol)
    }

    fn validate_observation(&self, x_new: &[f64], y_new: f64) -> Result<(), KrigingError> {
        if x_new.len() != self.kernel.dim() {
            return Err(KrigingError::DimMismatch {
                x_cols: x_new.len(),
                kernel_dim: self.kernel.dim(),
            });
        }
        if !y_new.is_finite() || x_new.iter().any(|v| !v.is_finite()) {
            crate::obs::health::counters().note_nonfinite();
            return Err(KrigingError::NonFinite("observation"));
        }
        Ok(())
    }

    /// Re-concentrate on the candidate state and, only if that succeeds,
    /// swap everything in — the single commit point of the online ops.
    fn commit(&mut self, x: Matrix, y: Vec<f64>, chol: Cholesky) -> Result<(), KrigingError> {
        let (alpha, one_c_one, mu_hat, sigma2, nll) = concentrate(&chol, &y)?;
        // The factor changed: any cached conditioning probe is stale.
        // Recomputing here would put an O(n²) estimator on the online
        // observe path, so invalidate and let the next health consumer
        // (doctor, metricsx) probe lazily.
        self.health = None;
        self.x = Arc::new(x);
        self.y = y;
        self.chol = chol;
        self.alpha = alpha;
        self.one_c_one = one_c_one;
        self.mu_hat = mu_hat;
        self.sigma2 = sigma2;
        self.nll = nll;
        Ok(())
    }

    /// Posterior mean and Kriging variance at each row of `xt` (m×d).
    ///
    /// Batched: assembles the m×n cross-correlation block and runs the
    /// triangular solves with all points as simultaneous right-hand
    /// sides (`Cholesky::solve_matrix`), streaming the factor once per
    /// chunk instead of once per point — the predict hot path (§Perf).
    pub fn predict(&self, xt: &Matrix) -> Result<Prediction, KrigingError> {
        self.predict_with_workers(xt, default_workers())
    }

    /// [`Self::predict`] with an explicit worker budget for the
    /// cross-correlation assembly. Pass 1 from already-parallel contexts
    /// (e.g. Cluster Kriging's per-model batch predict) so the assembly
    /// doesn't spawn `workers²` threads.
    pub fn predict_with_workers(
        &self,
        xt: &Matrix,
        workers: usize,
    ) -> Result<Prediction, KrigingError> {
        let m = xt.rows();
        let mut mean = vec![0.0; m];
        let mut variance = vec![0.0; m];
        self.predict_into_with_workers(xt, workers, &mut mean, &mut variance)?;
        Ok(Prediction { mean, variance })
    }

    /// [`Self::predict_with_workers`] into caller-provided buffers — the
    /// serving hot path. `mean` and `variance` must each hold exactly
    /// `xt.rows()` elements; values are identical to the allocating form.
    pub fn predict_into_with_workers(
        &self,
        xt: &Matrix,
        workers: usize,
        mean: &mut [f64],
        variance: &mut [f64],
    ) -> Result<(), KrigingError> {
        if xt.cols() != self.kernel.dim() {
            return Err(KrigingError::DimMismatch {
                x_cols: xt.cols(),
                kernel_dim: self.kernel.dim(),
            });
        }
        let m = xt.rows();
        let n = self.x.rows();
        assert_eq!(mean.len(), m, "predict_into: mean buffer size");
        assert_eq!(variance.len(), m, "predict_into: variance buffer size");
        // Chunk to bound the n×chunk solve workspace.
        const CHUNK: usize = 256;
        let workers = workers.max(1);
        for start in (0..m).step_by(CHUNK) {
            let rows: Vec<usize> = (start..(start + CHUNK).min(m)).collect();
            let xt_chunk = xt.select_rows(&rows);
            // Vectorized assembly: GEMM trick for SE, row-parallel scalar
            // otherwise (falls back to the plain loop for tiny chunks).
            let rt = trace::span("kernel-assembly", || {
                self.kernel.cross_corr_fast(&xt_chunk, &self.x, workers)
            }); // c×n
            let c_inv_r =
                trace::span("triangular-solve", || self.chol.solve_matrix(&rt.transpose())); // n×c
            for (ci, &row) in rows.iter().enumerate() {
                let r = rt.row(ci);
                let mut mu = self.mu_hat;
                let mut r_c_r = 0.0;
                let mut one_c_r = 0.0;
                for j in 0..n {
                    mu += r[j] * self.alpha[j];
                    let v = c_inv_r[(j, ci)];
                    r_c_r += r[j] * v;
                    one_c_r += v;
                }
                let t = 1.0 - one_c_r;
                let var =
                    self.sigma2 * (self.nugget + 1.0 - r_c_r + t * t / self.one_c_one);
                mean[row] = mu;
                variance[row] = var.max(0.0);
            }
        }
        Ok(())
    }

    /// Posterior mean only — O(n·d) per point (one correlation row
    /// dotted with α), skipping the O(n²) variance solve. The streaming
    /// residual pass calls this once per streamed row, where the full
    /// [`Self::predict_one`] would turn ingestion quadratic in the
    /// coarse-model size.
    pub fn predict_mean_one(&self, xt: &[f64]) -> f64 {
        let n = self.x.rows();
        let mut mu = self.mu_hat;
        for j in 0..n {
            mu += self.kernel.corr(xt, self.x.row(j)) * self.alpha[j];
        }
        mu
    }

    /// Single-point prediction (used by the router fast path).
    pub fn predict_one(&self, xt: &[f64]) -> (f64, f64) {
        let n = self.x.rows();
        // r(x): correlations to the training points.
        let mut r = Vec::with_capacity(n);
        for j in 0..n {
            r.push(self.kernel.corr(xt, self.x.row(j)));
        }
        // Mean: μ̂ + rᵀα.
        let mut mu = self.mu_hat;
        for j in 0..n {
            mu += r[j] * self.alpha[j];
        }
        // Variance (Eq. 5): σ̂²(λ + 1 − rᵀC⁻¹r + (1 − 1ᵀC⁻¹r)²/1ᵀC⁻¹1).
        let c_inv_r = self.chol.solve(&r);
        let r_c_r: f64 = r.iter().zip(&c_inv_r).map(|(a, b)| a * b).sum();
        let one_c_r: f64 = c_inv_r.iter().sum();
        let trend_term = {
            let t = 1.0 - one_c_r;
            t * t / self.one_c_one
        };
        let var = self.sigma2 * (self.nugget + 1.0 - r_c_r + trend_term);
        (mu, var.max(0.0))
    }

    /// Number of training points.
    pub fn n_train(&self) -> usize {
        self.x.rows()
    }

    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    pub fn nugget(&self) -> f64 {
        self.nugget
    }

    /// Estimated constant trend μ̂.
    pub fn mu_hat(&self) -> f64 {
        self.mu_hat
    }

    /// Estimated process variance σ̂².
    pub fn sigma2(&self) -> f64 {
        self.sigma2
    }

    /// Concentrated negative log-likelihood at the fitted parameters
    /// (lower is better; comparable across θ on the same data only).
    pub fn nll(&self) -> f64 {
        self.nll
    }

    /// Training inputs (used by the PJRT predict path and diagnostics).
    pub fn x_train(&self) -> &Matrix {
        &self.x
    }

    /// Training targets (kept for online updates and refit snapshots).
    pub fn y_train(&self) -> &[f64] {
        &self.y
    }

    /// Prediction weights α = C⁻¹(y − μ̂1).
    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    /// Run the numerical-health probe and cache the result: a Hager
    /// 1-norm condition estimate off the existing factor (O(n²)) plus
    /// the escalated jitter. Called once per fit/refit — never from the
    /// predict path — and skipped entirely when
    /// [`crate::obs::health::set_probes_enabled`] turned probes off.
    pub fn probe_health(&mut self) {
        if crate::obs::health::probes_enabled() {
            self.health = Some(self.compute_health());
        }
    }

    fn compute_health(&self) -> ModelHealth {
        ModelHealth {
            cond_estimate: self.chol.condest_1norm(),
            jitter: self.chol.jitter(),
            n: self.x.rows(),
        }
    }

    /// The cached fit-time health probe, if one ran and no online update
    /// invalidated it since.
    pub fn health(&self) -> Option<ModelHealth> {
        self.health
    }

    /// Health snapshot, computing the condition estimate on demand when
    /// no cached probe is available. O(n²) worst case — strictly for the
    /// doctor/metrics paths, never the predict hot path.
    pub fn health_or_probe(&self) -> ModelHealth {
        self.health.unwrap_or_else(|| self.compute_health())
    }

    /// Approximate bytes of fitted state resident in memory: the n×n
    /// factor dominates, plus training inputs, targets, and weights.
    /// Lets the serving `stats`/`health` ops make window eviction and
    /// the streaming memory budget observable.
    pub fn resident_bytes(&self) -> usize {
        let (n, d) = self.x.shape();
        (n * n + n * d + 2 * n) * std::mem::size_of::<f64>()
    }

    /// Serialize every fitted quantity — including the Cholesky factor,
    /// so loading is O(n²) I/O with no refactorization and the loaded
    /// model predicts bit-identically to this one.
    pub(crate) fn write_artifact(&self, w: &mut crate::util::binio::BinWriter) {
        w.put_str(self.kernel.kind.name());
        w.put_f64_slice(&self.kernel.theta);
        w.put_f64(self.nugget);
        w.put_matrix(&self.x);
        w.put_matrix(self.chol.l());
        w.put_f64(self.chol.jitter());
        w.put_f64_slice(&self.alpha);
        w.put_f64(self.one_c_one);
        w.put_f64(self.mu_hat);
        w.put_f64(self.sigma2);
        w.put_f64(self.nll);
        // v2: training targets (online state). Appended last so the v1
        // field order above is a strict prefix.
        w.put_f64_slice(&self.y);
        // v5: optional health probe. Only the condition estimate needs
        // storing — jitter and n are already recoverable from the fields
        // above, and a flag byte keeps unprobed models honest (`None`
        // stays `None` across a save/load round trip).
        match self.health {
            Some(h) => {
                w.put_bool(true);
                w.put_f64(h.cond_estimate);
            }
            None => w.put_bool(false),
        }
    }

    /// Inverse of [`Self::write_artifact`]; validates cross-field shape
    /// consistency so a corrupted payload is a recoverable error.
    /// `version` is the enclosing artifact's container version: v2
    /// payloads carry the training targets; for v1 payloads `y` is
    /// reconstructed from the stored factor via `y = L·Lᵀ·α + μ̂·1` (O(n²)),
    /// so pre-online artifacts stay fully observable.
    pub(crate) fn read_artifact(
        r: &mut crate::util::binio::BinReader<'_>,
        version: u32,
    ) -> anyhow::Result<Self> {
        use anyhow::{ensure, Context};
        let kind_name = r.get_str()?;
        let kind = crate::kernel::KernelKind::from_name(&kind_name)
            .with_context(|| format!("unknown kernel family {kind_name:?}"))?;
        let theta = r.get_f64_vec()?;
        ensure!(
            !theta.is_empty() && theta.iter().all(|&t| t > 0.0 && t.is_finite()),
            "invalid kernel θ in artifact"
        );
        let nugget = r.get_f64()?;
        let x = r.get_matrix()?;
        let l = r.get_matrix()?;
        let jitter = r.get_f64()?;
        let alpha = r.get_f64_vec()?;
        let one_c_one = r.get_f64()?;
        let mu_hat = r.get_f64()?;
        let sigma2 = r.get_f64()?;
        let nll = r.get_f64()?;
        let n = x.rows();
        ensure!(n > 0, "artifact has an empty training set");
        ensure!(x.cols() == theta.len(), "x/θ dimension mismatch in artifact");
        ensure!(l.rows() == n && l.cols() == n, "factor/x shape mismatch in artifact");
        ensure!(alpha.len() == n, "α/x length mismatch in artifact");
        let y = if version >= 2 {
            let y = r.get_f64_vec()?;
            ensure!(y.len() == n, "y/x length mismatch in artifact");
            y
        } else {
            // The fit solved α through the (possibly jittered) factor
            // itself — α = (L·Lᵀ)⁻¹(y − μ̂·1) — so inverting it is exactly
            // y = L·(Lᵀα) + μ̂·1, with no jitter correction.
            let t = l.matvec_t(&alpha);
            let lt = l.matvec(&t);
            (0..n).map(|i| lt[i] + mu_hat).collect()
        };
        let chol = Cholesky::from_parts(l, jitter)?;
        let health = if version >= 5 && r.get_bool()? {
            let cond_estimate = r.get_f64()?;
            Some(ModelHealth { cond_estimate, jitter: chol.jitter(), n })
        } else {
            None
        };
        Ok(Self {
            kernel: Kernel::new(kind, theta),
            nugget,
            x: Arc::new(x),
            y,
            chol,
            alpha,
            one_c_one,
            mu_hat,
            sigma2,
            nll,
            health,
        })
    }
}

/// New matrix with `row` appended (O(n·d) copy — the O(n²) solves
/// dominate every caller).
fn append_row(x: &Matrix, row: &[f64]) -> Matrix {
    let (n, d) = x.shape();
    let mut data = Vec::with_capacity((n + 1) * d);
    data.extend_from_slice(x.as_slice());
    data.extend_from_slice(row);
    Matrix::from_vec(n + 1, d, data)
}

/// A silent conditioning change is the one thing an online model must
/// not do: when an incremental factor update falls back to the full
/// jitter-escalating refactorization, record it in the degeneracy
/// counters and the structured log with the jitter it landed on.
fn note_factor_fallback(op: &'static str, n: usize, jitter: f64) {
    crate::obs::health::counters().note_factor_fallback();
    log::warn!(
        "factor_full fallback in {op}: incremental update hit a non-PD pivot \
         (n={n}, escalated jitter={jitter:.3e})"
    );
}

/// Factor `R(x) + nugget·I` from scratch with jitter escalation — the
/// rare fallback when an incremental factor update hits a non-PD pivot.
/// Uses the machine's worker budget: online updates run on a serving
/// thread (not nested inside a fit pool), and at large n this O(n³) path
/// executes under the adapter's write lock, so wall-clock matters.
fn factor_full(kernel: &Kernel, x: &Matrix, nugget: f64) -> Result<Cholesky, KrigingError> {
    let workers = default_workers();
    let mut c = kernel.corr_matrix_parallel(x, workers);
    for i in 0..x.rows() {
        c[(i, i)] += nugget;
    }
    Ok(Cholesky::new_regularized_with_workers(&c, workers)?)
}

/// Concentrated estimates given a factored `C = R + λI` and targets `y`:
/// returns `(α, 1ᵀC⁻¹1, μ̂, σ̂², NLL)`. Shared by the fit tail and the
/// online re-solve after an incremental factor update.
fn concentrate(
    chol: &Cholesky,
    y: &[f64],
) -> Result<(Vec<f64>, f64, f64, f64, f64), KrigingError> {
    let n = y.len();
    debug_assert_eq!(chol.dim(), n, "concentrate: factor/target size mismatch");
    // μ̂ = (1ᵀC⁻¹y)/(1ᵀC⁻¹1)  (MAP trend, Eq. 4 right).
    let ones = vec![1.0; n];
    let c_inv_one = chol.solve(&ones);
    let c_inv_y = chol.solve(y);
    let one_c_one: f64 = c_inv_one.iter().sum();
    let one_c_y: f64 = c_inv_y.iter().sum();
    let mu_hat = one_c_y / one_c_one;

    // α = C⁻¹(y − μ̂1) = C⁻¹y − μ̂·C⁻¹1.
    let alpha: Vec<f64> = c_inv_y.iter().zip(&c_inv_one).map(|(a, b)| a - mu_hat * b).collect();

    // σ̂² = (y−μ̂1)ᵀC⁻¹(y−μ̂1)/n.
    let resid_quad: f64 = y.iter().zip(&alpha).map(|(yi, ai)| (yi - mu_hat) * ai).sum();
    let sigma2 = (resid_quad / n as f64).max(1e-300);

    // Concentrated NLL (up to an additive constant): n·ln σ̂² + ln|C|, halved.
    let nll = 0.5 * (n as f64 * sigma2.ln() + chol.log_det());
    if !nll.is_finite() {
        return Err(KrigingError::NonFinite("likelihood"));
    }
    Ok((alpha, one_c_one, mu_hat, sigma2, nll))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelKind;
    use crate::util::proptest::{check_default, gen_matrix, gen_size};
    use crate::util::rng::Rng;

    fn toy_model(n: usize, seed: u64, nugget: f64) -> (OrdinaryKriging, Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x = gen_matrix(&mut rng, n, 2, -2.0, 2.0);
        let y: Vec<f64> =
            (0..n).map(|i| (x.row(i)[0]).sin() + 0.5 * x.row(i)[1]).collect();
        let kernel = Kernel::new(KernelKind::SquaredExponential, vec![1.0, 1.0]);
        let m = OrdinaryKriging::fit(x.clone(), &y, kernel, nugget).unwrap();
        (m, x, y)
    }

    #[test]
    fn interpolates_training_points_with_zero_nugget() {
        let (m, x, y) = toy_model(30, 1, 0.0);
        let pred = m.predict(&x).unwrap();
        for i in 0..x.rows() {
            assert!(
                (pred.mean[i] - y[i]).abs() < 1e-5,
                "no interpolation at {i}: {} vs {}",
                pred.mean[i],
                y[i]
            );
            // Kriging variance ~0 at training points.
            assert!(pred.variance[i] < 1e-5, "variance {} at train point", pred.variance[i]);
        }
    }

    #[test]
    fn variance_grows_away_from_data() {
        let (m, _, _) = toy_model(25, 2, 1e-8);
        let near = m.predict_one(&[0.1, 0.1]).1;
        let far = m.predict_one(&[50.0, 50.0]).1;
        assert!(far > near, "far variance {far} <= near {near}");
        // Far from data the posterior reverts to ~σ̂²(1+λ+1/1ᵀC⁻¹1) > σ̂².
        assert!(far >= m.sigma2() * 0.9);
    }

    #[test]
    fn far_prediction_reverts_to_trend() {
        let (m, _, _) = toy_model(25, 3, 1e-8);
        let (mu, _) = m.predict_one(&[100.0, -100.0]);
        assert!((mu - m.mu_hat()).abs() < 1e-6);
    }

    #[test]
    fn constant_data_yields_constant_prediction() {
        let x = Matrix::from_rows(&[&[0.0], &[1.0], &[2.0]]);
        let y = [5.0, 5.0, 5.0];
        let kernel = Kernel::se_isotropic(1, 1.0);
        let m = OrdinaryKriging::fit(x, &y, kernel, 1e-6).unwrap();
        assert!((m.mu_hat() - 5.0).abs() < 1e-9);
        let (mu, _) = m.predict_one(&[0.5]);
        assert!((mu - 5.0).abs() < 1e-9);
    }

    #[test]
    fn prediction_equivariant_under_y_shift_prop() {
        // Shifting y by a constant shifts predictions by the same constant
        // and leaves variances unchanged (ordinary Kriging handles trend).
        check_default(|rng| {
            let n = gen_size(rng, 5, 25);
            let x = gen_matrix(rng, n, 2, -1.0, 1.0);
            let y: Vec<f64> = (0..n).map(|i| x.row(i)[0] * x.row(i)[1]).collect();
            let shifted: Vec<f64> = y.iter().map(|v| v + 37.5).collect();
            let kern = Kernel::se_isotropic(2, 0.8);
            let m1 = OrdinaryKriging::fit(x.clone(), &y, kern.clone(), 1e-6)
                .map_err(|e| e.to_string())?;
            let m2 = OrdinaryKriging::fit(x.clone(), &shifted, kern, 1e-6)
                .map_err(|e| e.to_string())?;
            let xt = gen_matrix(rng, 5, 2, -1.5, 1.5);
            let p1 = m1.predict(&xt).map_err(|e| e.to_string())?;
            let p2 = m2.predict(&xt).map_err(|e| e.to_string())?;
            for i in 0..5 {
                crate::prop_assert!(
                    (p2.mean[i] - p1.mean[i] - 37.5).abs() < 1e-6,
                    "mean not equivariant at {i}"
                );
                crate::prop_assert!(
                    (p2.variance[i] - p1.variance[i]).abs() < 1e-6,
                    "variance changed under shift at {i}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn nugget_smooths_interpolation() {
        // With a large nugget the model should NOT interpolate noisy data.
        let mut rng = Rng::new(9);
        let x = gen_matrix(&mut rng, 40, 1, -2.0, 2.0);
        let y: Vec<f64> =
            (0..40).map(|i| x.row(i)[0].sin() + rng.normal_with(0.0, 0.3)).collect();
        let kern = Kernel::se_isotropic(1, 1.0);
        let interp = OrdinaryKriging::fit(x.clone(), &y, kern.clone(), 1e-10).unwrap();
        let smooth = OrdinaryKriging::fit(x.clone(), &y, kern, 0.5).unwrap();
        let pi = interp.predict(&x).unwrap();
        let ps = smooth.predict(&x).unwrap();
        let err_i: f64 = pi.mean.iter().zip(&y).map(|(a, b)| (a - b).abs()).sum();
        let err_s: f64 = ps.mean.iter().zip(&y).map(|(a, b)| (a - b).abs()).sum();
        assert!(err_i < err_s, "nugget did not smooth: {err_i} vs {err_s}");
    }

    #[test]
    fn error_cases() {
        let kern = Kernel::se_isotropic(2, 1.0);
        assert!(matches!(
            OrdinaryKriging::fit(Matrix::zeros(0, 2), &[], kern.clone(), 0.0),
            Err(KrigingError::EmptyTrainingSet)
        ));
        assert!(matches!(
            OrdinaryKriging::fit(Matrix::zeros(2, 3), &[0.0, 0.0], kern.clone(), 0.0),
            Err(KrigingError::DimMismatch { .. })
        ));
        assert!(matches!(
            OrdinaryKriging::fit(Matrix::zeros(2, 2), &[0.0], kern.clone(), 0.0),
            Err(KrigingError::RowMismatch { .. })
        ));
        assert!(matches!(
            OrdinaryKriging::fit(Matrix::zeros(2, 2), &[f64::NAN, 0.0], kern, 0.0),
            Err(KrigingError::NonFinite(_))
        ));
    }

    #[test]
    fn fit_with_cache_bit_identical_to_fit() {
        // The cached assembly reproduces the scalar accumulation order, so
        // NLL and predictions must match to the last bit for every family.
        let mut rng = Rng::new(21);
        let x = gen_matrix(&mut rng, 50, 3, -2.0, 2.0);
        let y: Vec<f64> = (0..50).map(|i| x.row(i)[0].sin() + 0.2 * x.row(i)[2]).collect();
        let xt = gen_matrix(&mut rng, 17, 3, -2.5, 2.5);
        for kind in [
            KernelKind::SquaredExponential,
            KernelKind::Matern52,
            KernelKind::Matern32,
            KernelKind::AbsoluteExponential,
        ] {
            let kernel = Kernel::new(kind, vec![0.8, 1.7, 0.09]);
            let plain = OrdinaryKriging::fit(x.clone(), &y, kernel.clone(), 1e-8).unwrap();
            let cache = crate::kernel::cache::DistanceCache::new(&x, kind, 4);
            let cached = OrdinaryKriging::fit_with_cache(
                std::sync::Arc::new(x.clone()),
                &y,
                kernel,
                1e-8,
                &cache,
                4,
            )
            .unwrap();
            assert_eq!(plain.nll().to_bits(), cached.nll().to_bits(), "{kind:?}: NLL bits");
            assert_eq!(plain.mu_hat().to_bits(), cached.mu_hat().to_bits(), "{kind:?}: μ̂ bits");
            let pp = plain.predict(&xt).unwrap();
            let pc = cached.predict(&xt).unwrap();
            for i in 0..xt.rows() {
                assert_eq!(pp.mean[i].to_bits(), pc.mean[i].to_bits(), "{kind:?}: mean {i}");
                assert_eq!(
                    pp.variance[i].to_bits(),
                    pc.variance[i].to_bits(),
                    "{kind:?}: variance {i}"
                );
            }
        }
    }

    #[test]
    fn fit_with_cache_rejects_mismatched_cache() {
        let mut rng = Rng::new(22);
        let x = gen_matrix(&mut rng, 20, 2, -1.0, 1.0);
        let other = gen_matrix(&mut rng, 12, 2, -1.0, 1.0);
        let y = vec![0.0; 20];
        let cache =
            crate::kernel::cache::DistanceCache::new(&other, KernelKind::SquaredExponential, 1);
        let kern = Kernel::se_isotropic(2, 1.0);
        let x = std::sync::Arc::new(x);
        assert!(matches!(
            OrdinaryKriging::fit_with_cache(
                std::sync::Arc::clone(&x),
                &y,
                kern,
                1e-8,
                &cache,
                1
            ),
            Err(KrigingError::CacheMismatch { .. })
        ));
        // Metric mismatch is a recoverable error too, not a panic.
        let sq_cache =
            crate::kernel::cache::DistanceCache::new(&x, KernelKind::SquaredExponential, 1);
        let abs_kern = Kernel::new(KernelKind::AbsoluteExponential, vec![1.0, 1.0]);
        assert!(matches!(
            OrdinaryKriging::fit_with_cache(x, &y, abs_kern, 1e-8, &sq_cache, 1),
            Err(KrigingError::CacheMismatch { .. })
        ));
    }

    #[test]
    fn observe_point_matches_fit_from_scratch() {
        let (mut m, x, y) = toy_model(30, 8, 1e-6);
        let mut rng = Rng::new(99);
        let xs = gen_matrix(&mut rng, 5, 2, -2.0, 2.0);
        let mut x_all = x.clone();
        let mut y_all = y.clone();
        for i in 0..5 {
            let yi = xs.row(i)[0].sin() + 0.5 * xs.row(i)[1];
            m.observe_point(xs.row(i), yi).unwrap();
            x_all = x_all.vstack(&Matrix::from_vec(1, 2, xs.row(i).to_vec()));
            y_all.push(yi);
        }
        let fresh = OrdinaryKriging::fit(x_all, &y_all, m.kernel().clone(), 1e-6).unwrap();
        assert!((m.mu_hat() - fresh.mu_hat()).abs() < 1e-9);
        assert!((m.sigma2() - fresh.sigma2()).abs() / fresh.sigma2() < 1e-8);
        let probe = gen_matrix(&mut rng, 10, 2, -2.5, 2.5);
        let po = m.predict(&probe).unwrap();
        let pf = fresh.predict(&probe).unwrap();
        for i in 0..10 {
            let scale = pf.mean[i].abs().max(1.0);
            assert!(
                (po.mean[i] - pf.mean[i]).abs() / scale < 1e-8,
                "mean diverged at {i}: {} vs {}",
                po.mean[i],
                pf.mean[i]
            );
            let vscale = pf.variance[i].max(1e-12);
            assert!(
                (po.variance[i] - pf.variance[i]).abs() / vscale < 1e-6,
                "variance diverged at {i}: {} vs {}",
                po.variance[i],
                pf.variance[i]
            );
        }
    }

    #[test]
    fn replace_point_matches_fit_from_scratch() {
        let (mut m, x, y) = toy_model(25, 12, 1e-6);
        let new_x = [0.33, -0.7];
        let new_y = 0.9;
        m.replace_point(7, &new_x, new_y).unwrap();
        let keep: Vec<usize> = (0..25).filter(|&j| j != 7).collect();
        let x_ref =
            x.select_rows(&keep).vstack(&Matrix::from_vec(1, 2, new_x.to_vec()));
        let mut y_ref: Vec<f64> = keep.iter().map(|&j| y[j]).collect();
        y_ref.push(new_y);
        let fresh = OrdinaryKriging::fit(x_ref, &y_ref, m.kernel().clone(), 1e-6).unwrap();
        let (mo, vo) = m.predict_one(&[0.2, 0.4]);
        let (mf, vf) = fresh.predict_one(&[0.2, 0.4]);
        assert!((mo - mf).abs() < 1e-8, "{mo} vs {mf}");
        assert!((vo - vf).abs() < 1e-8, "{vo} vs {vf}");
        assert_eq!(m.n_train(), 25);
        assert_eq!(m.y_train().len(), 25);
    }

    #[test]
    fn forget_point_matches_fit_from_scratch() {
        let (mut m, x, y) = toy_model(25, 13, 1e-6);
        m.forget_point(11).unwrap();
        assert_eq!(m.n_train(), 24);
        let keep: Vec<usize> = (0..25).filter(|&j| j != 11).collect();
        let y_ref: Vec<f64> = keep.iter().map(|&j| y[j]).collect();
        let fresh =
            OrdinaryKriging::fit(x.select_rows(&keep), &y_ref, m.kernel().clone(), 1e-6).unwrap();
        let (mo, vo) = m.predict_one(&[0.2, 0.4]);
        let (mf, vf) = fresh.predict_one(&[0.2, 0.4]);
        assert!((mo - mf).abs() < 1e-8, "{mo} vs {mf}");
        assert!((vo - vf).abs() < 1e-8, "{vo} vs {vf}");
    }

    #[test]
    fn forget_point_refuses_to_empty_the_model() {
        let x = Matrix::from_rows(&[&[0.0, 0.0]]);
        let kern = Kernel::se_isotropic(2, 1.0);
        let mut m = OrdinaryKriging::fit(x, &[1.0], kern, 1e-8).unwrap();
        assert!(matches!(m.forget_point(0), Err(KrigingError::EmptyTrainingSet)));
        assert_eq!(m.n_train(), 1, "failed forget mutated the model");
    }

    #[test]
    fn observe_duplicate_point_falls_back_to_refactor() {
        // With a negligible nugget, appending an exact duplicate of a
        // training point makes C singular; the incremental append fails
        // and the jitter-escalating refactorization must rescue it —
        // and the fallback must be visible in the degeneracy counters,
        // not silent (the pre-fix behavior).
        let before = crate::obs::health::counters().snapshot();
        let (mut m, x, _) = toy_model(15, 14, 1e-12);
        let dup = x.row(3).to_vec();
        m.observe_point(&dup, 1.25).unwrap();
        assert_eq!(m.n_train(), 16);
        let pred = m.predict(&x).unwrap();
        assert!(pred.mean.iter().all(|v| v.is_finite()));
        let delta = crate::obs::health::counters().snapshot().delta_since(&before);
        assert!(delta.factor_fallbacks >= 1, "fallback not counted");
        assert!(delta.jitter_escalations >= 1, "escalation not counted");
    }

    #[test]
    fn health_probe_lifecycle() {
        // fit() probes; the probe survives artifact-free cloning; online
        // updates invalidate it; health_or_probe recomputes on demand.
        let (mut m, _, _) = toy_model(20, 31, 1e-8);
        let h = m.health().expect("fit should probe health");
        assert!(h.cond_estimate.is_finite() && h.cond_estimate >= 1.0);
        assert_eq!(h.jitter, 0.0, "well-conditioned toy fit needed jitter");
        assert_eq!(h.n, 20);
        assert_eq!(h.class(), crate::obs::health::HealthClass::Ok);

        m.observe_point(&[0.31, -0.41], 0.2).unwrap();
        assert!(m.health().is_none(), "online update must invalidate the probe");
        let lazy = m.health_or_probe();
        assert_eq!(lazy.n, 21);
        assert!(lazy.cond_estimate.is_finite() && lazy.cond_estimate >= 1.0);

        // With probes disabled, fits skip the estimator entirely.
        crate::obs::health::set_probes_enabled(false);
        let (m2, _, _) = toy_model(10, 32, 1e-8);
        crate::obs::health::set_probes_enabled(true);
        assert!(m2.health().is_none(), "disabled probes still ran");
    }

    #[test]
    fn observe_rejects_bad_input() {
        let (mut m, _, _) = toy_model(10, 15, 1e-8);
        assert!(matches!(
            m.observe_point(&[1.0], 0.0),
            Err(KrigingError::DimMismatch { .. })
        ));
        assert!(matches!(
            m.observe_point(&[1.0, 2.0], f64::NAN),
            Err(KrigingError::NonFinite(_))
        ));
        assert_eq!(m.n_train(), 10, "rejected observation mutated the model");
    }

    #[test]
    fn better_theta_has_lower_nll() {
        // Data generated with a length scale ~1; θ=1 should beat θ=100.
        let mut rng = Rng::new(4);
        let x = gen_matrix(&mut rng, 60, 1, -3.0, 3.0);
        let y: Vec<f64> = (0..60).map(|i| (1.5 * x.row(i)[0]).sin()).collect();
        let good = OrdinaryKriging::fit(
            x.clone(),
            &y,
            Kernel::se_isotropic(1, 1.0),
            1e-8,
        )
        .unwrap();
        let bad = OrdinaryKriging::fit(
            x.clone(),
            &y,
            Kernel::se_isotropic(1, 1e4),
            1e-8,
        )
        .unwrap();
        assert!(good.nll() < bad.nll(), "{} vs {}", good.nll(), bad.nll());
    }
}
