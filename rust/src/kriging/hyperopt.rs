//! Hyper-parameter estimation for Kriging models.
//!
//! The paper (§II) estimates θ (and optionally the nugget) by maximizing
//! the concentrated log-likelihood. We search over `log10 θ` with a
//! multi-start Nelder–Mead simplex — derivative-free, robust to the
//! multimodal likelihood surfaces Kriging exhibits, and each evaluation is
//! one `O(n³)` model fit, which is exactly the cost structure Cluster
//! Kriging is designed to shrink.

use crate::kernel::cache::DistanceCache;
use crate::kernel::{Kernel, KernelKind};
use crate::kriging::model::{KrigingError, OrdinaryKriging};
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Search-space and budget configuration.
#[derive(Debug, Clone)]
pub struct HyperOpt {
    pub kind: KernelKind,
    /// log10 θ bounds (inclusive). Paper-style default: θ ∈ [1e-2, 1e2].
    pub log_theta_bounds: (f64, f64),
    /// Relative nugget λ. `Fixed(v)` uses v; `Estimate` adds log10 λ as an
    /// extra search dimension within the given bounds (paper §VII mentions
    /// nugget optimization as future work — we implement it).
    pub nugget: NuggetMode,
    /// Nelder–Mead restarts (first start is the space's center).
    pub restarts: usize,
    /// Max objective evaluations per restart.
    pub max_evals: usize,
    /// Use one shared θ for all dimensions (isotropic) instead of
    /// per-dimension anisotropic θ. Cuts the search dimension from d to 1.
    pub isotropic: bool,
    /// Worker threads for assembly + factorization inside the objective.
    /// `None` → the machine default, so top-level single-model searches
    /// (SoD, BCM's shared pre-fit, a plain `HyperOpt::fit`) use all
    /// cores. Contexts that already run fits on a worker pool override
    /// this — `ClusterKriging::fit` splits the budget across clusters —
    /// since nesting full pools oversubscribes the machine. The fitted
    /// model is identical for any worker count.
    pub assembly_workers: Option<usize>,
    pub seed: u64,
    /// Optional fit-path telemetry sink: when set, every objective
    /// evaluation records its decoded θ/nugget, the resulting NLL,
    /// whether it improved the restart's incumbent, and its wall time
    /// (see [`crate::obs::fitlog`]). `None` (the default) keeps the
    /// objective's hot loop clock-free. Recording never perturbs the
    /// search itself — fitted models are bit-identical either way.
    pub telemetry: Option<crate::obs::FitSink>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NuggetMode {
    Fixed(f64),
    /// Estimate log10 λ within these bounds.
    Estimate { log_bounds: (f64, f64) },
}

impl Default for HyperOpt {
    fn default() -> Self {
        Self {
            kind: KernelKind::SquaredExponential,
            log_theta_bounds: (-2.0, 2.0),
            nugget: NuggetMode::Fixed(1e-8),
            restarts: 3,
            max_evals: 60,
            isotropic: false,
            assembly_workers: None,
            seed: 0x5EED,
            telemetry: None,
        }
    }
}

impl HyperOpt {
    /// Budget preset for large clusters where each evaluation is costly.
    pub fn fast() -> Self {
        Self { restarts: 2, max_evals: 30, ..Self::default() }
    }

    /// Fit a model with ML-estimated hyper-parameters.
    pub fn fit(&self, x: Matrix, y: &[f64]) -> Result<OrdinaryKriging, KrigingError> {
        self.fit_shared(Arc::new(x), y)
    }

    /// [`Self::fit`] over a shared training matrix.
    ///
    /// The whole multi-start search runs against **one** θ-independent
    /// [`DistanceCache`] built up front, so each of the ~restarts×evals
    /// objective evaluations assembles `R = g(Σθᵢ Dᵢ)` from flat cached
    /// planes instead of a fresh O(n²d) scalar pass — and shares `x` by
    /// reference instead of cloning it per evaluation. Oversized caches
    /// (see [`crate::kernel::cache::MAX_CACHE_ENTRIES`]) fall back to the
    /// scalar per-evaluation path transparently.
    pub fn fit_shared(&self, x: Arc<Matrix>, y: &[f64]) -> Result<OrdinaryKriging, KrigingError> {
        let d = x.cols().max(1);
        let theta_dims = if self.isotropic { 1 } else { d };
        let (lo, hi) = self.log_theta_bounds;
        let workers = self
            .assembly_workers
            .unwrap_or_else(crate::util::threadpool::default_workers)
            .max(1);
        let cache = DistanceCache::try_new(&x, self.kind, workers);

        let mut rng = Rng::new(self.seed ^ (x.rows() as u64) << 16 ^ d as u64);
        let mut best: Option<OrdinaryKriging> = None;

        // Objective: NLL of the model at decoded parameters; returns the
        // fitted model so the best one is kept without a refit.
        let decode = |p: &[f64]| -> (Vec<f64>, f64) {
            let theta: Vec<f64> = if self.isotropic {
                vec![10f64.powf(p[0].clamp(lo, hi)); d]
            } else {
                (0..d).map(|i| 10f64.powf(p[i].clamp(lo, hi))).collect()
            };
            let nugget = match self.nugget {
                NuggetMode::Fixed(v) => v,
                NuggetMode::Estimate { log_bounds } => {
                    10f64.powf(p[theta_dims].clamp(log_bounds.0, log_bounds.1))
                }
            };
            (theta, nugget)
        };

        for restart in 0..self.restarts.max(1) {
            // Start point: center for the first restart, uniform random after.
            let start: Vec<f64> = if restart == 0 {
                let mut s = vec![0.5 * (lo + hi); theta_dims];
                if let NuggetMode::Estimate { log_bounds } = self.nugget {
                    s.push(0.5 * (log_bounds.0 + log_bounds.1));
                }
                s
            } else {
                let mut s = rng.uniform_vec(theta_dims, lo, hi);
                if let NuggetMode::Estimate { log_bounds } = self.nugget {
                    s.push(rng.uniform_in(log_bounds.0, log_bounds.1));
                }
                s
            };

            let mut local_best: Option<OrdinaryKriging> = None;
            let mut eval_idx = 0usize;
            let mut objective = |p: &[f64]| -> f64 {
                // Clocks only tick when a sink is attached: the bare
                // search pays one `is_some` branch per evaluation
                // (bench §O2 gates the recording overhead at ≤3%).
                let t0 = self.telemetry.as_ref().map(|_| std::time::Instant::now());
                let (theta, nugget) = decode(p);
                // Degeneracy signal: the simplex pressing the raw nugget
                // parameter against (or past) its search box means the
                // optimizer wants a λ outside the allowed range — the
                // data is noisier (or more degenerate) than the bounds
                // admit. One relaxed atomic per evaluation.
                if let NuggetMode::Estimate { log_bounds } = self.nugget {
                    let raw = p[theta_dims];
                    if raw <= log_bounds.0 || raw >= log_bounds.1 {
                        crate::obs::health::counters().note_nugget_boundary();
                    }
                }
                let kernel = Kernel::new(self.kind, theta);
                let fitted = match cache.as_ref() {
                    Some(c) => OrdinaryKriging::fit_with_cache(
                        Arc::clone(&x),
                        y,
                        kernel,
                        nugget,
                        c,
                        workers,
                    ),
                    None => OrdinaryKriging::fit_shared_with_workers(
                        Arc::clone(&x),
                        y,
                        kernel,
                        nugget,
                        workers,
                    ),
                };
                let mut accepted = false;
                let value = match fitted {
                    Ok(model) => {
                        let nll = model.nll();
                        let better = local_best
                            .as_ref()
                            .map(|b| nll < b.nll())
                            .unwrap_or(true);
                        if better {
                            local_best = Some(model);
                            accepted = true;
                        }
                        nll
                    }
                    Err(_) => f64::INFINITY,
                };
                if let Some(sink) = &self.telemetry {
                    let wall_us = t0.map(|t| t.elapsed().as_micros() as u64).unwrap_or(0);
                    let (theta, nugget) = decode(p);
                    let nll = value.is_finite().then_some(value);
                    sink.hyperopt_eval(
                        restart,
                        eval_idx,
                        &theta,
                        nugget,
                        nll,
                        accepted,
                        wall_us,
                    );
                }
                eval_idx += 1;
                value
            };
            nelder_mead(&start, 0.5, self.max_evals, &mut objective);

            if let Some(candidate) = local_best {
                let better =
                    best.as_ref().map(|b| candidate.nll() < b.nll()).unwrap_or(true);
                if better {
                    best = Some(candidate);
                }
            }
        }

        // One condition probe on the winning model only — the ~restarts×
        // evals interior fits skip it (bench §H1 gates the overhead).
        if let Some(m) = best.as_mut() {
            m.probe_health();
        }
        best.ok_or(KrigingError::NonFinite("likelihood (all restarts failed)"))
    }
}

/// Plain Nelder–Mead simplex minimization.
///
/// `step` is the initial simplex edge; terminates after `max_evals`
/// objective calls or simplex collapse. Returns the best point found.
pub fn nelder_mead(
    start: &[f64],
    step: f64,
    max_evals: usize,
    f: &mut dyn FnMut(&[f64]) -> f64,
) -> (Vec<f64>, f64) {
    let n = start.len();
    assert!(n > 0);
    let (alpha, gamma, rho, sigma) = (1.0, 2.0, 0.5, 0.5);

    let mut evals = 0usize;
    let mut eval = |p: &[f64], evals: &mut usize| -> f64 {
        *evals += 1;
        let v = f(p);
        if v.is_nan() {
            f64::INFINITY
        } else {
            v
        }
    };

    // Initial simplex: start + per-axis offsets.
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(n + 1);
    let v0 = eval(start, &mut evals);
    simplex.push((start.to_vec(), v0));
    for i in 0..n {
        let mut p = start.to_vec();
        p[i] += step;
        let v = eval(&p, &mut evals);
        simplex.push((p, v));
    }

    while evals < max_evals {
        simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        // Convergence: simplex value spread.
        if (simplex[n].1 - simplex[0].1).abs() < 1e-10 {
            break;
        }
        // Centroid of all but the worst.
        let mut centroid = vec![0.0; n];
        for (p, _) in &simplex[..n] {
            for i in 0..n {
                centroid[i] += p[i] / n as f64;
            }
        }
        let worst = simplex[n].clone();

        // Reflection.
        let refl: Vec<f64> =
            (0..n).map(|i| centroid[i] + alpha * (centroid[i] - worst.0[i])).collect();
        let refl_v = eval(&refl, &mut evals);

        if refl_v < simplex[0].1 {
            // Expansion.
            let exp: Vec<f64> =
                (0..n).map(|i| centroid[i] + gamma * (refl[i] - centroid[i])).collect();
            let exp_v = eval(&exp, &mut evals);
            simplex[n] = if exp_v < refl_v { (exp, exp_v) } else { (refl, refl_v) };
        } else if refl_v < simplex[n - 1].1 {
            simplex[n] = (refl, refl_v);
        } else {
            // Contraction.
            let con: Vec<f64> =
                (0..n).map(|i| centroid[i] + rho * (worst.0[i] - centroid[i])).collect();
            let con_v = eval(&con, &mut evals);
            if con_v < worst.1 {
                simplex[n] = (con, con_v);
            } else {
                // Shrink toward the best.
                let best = simplex[0].0.clone();
                for item in simplex.iter_mut().skip(1) {
                    for i in 0..n {
                        item.0[i] = best[i] + sigma * (item.0[i] - best[i]);
                    }
                    item.1 = eval(&item.0, &mut evals);
                }
            }
        }
    }

    simplex.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    simplex.swap_remove(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::gen_matrix;

    #[test]
    fn nelder_mead_minimizes_quadratic() {
        let mut f = |p: &[f64]| (p[0] - 3.0).powi(2) + (p[1] + 1.0).powi(2);
        let (p, v) = nelder_mead(&[0.0, 0.0], 1.0, 300, &mut f);
        assert!(v < 1e-6, "value {v}");
        assert!((p[0] - 3.0).abs() < 1e-3 && (p[1] + 1.0).abs() < 1e-3, "{p:?}");
    }

    #[test]
    fn nelder_mead_handles_nan_objective() {
        let mut f = |p: &[f64]| if p[0] < 0.0 { f64::NAN } else { p[0] * p[0] };
        let (p, v) = nelder_mead(&[2.0], 0.5, 100, &mut f);
        assert!(v < 1e-4);
        assert!(p[0].abs() < 0.1);
    }

    #[test]
    fn recovers_reasonable_length_scale() {
        // Data from a smooth 1-d function; ML θ should beat extremes.
        let mut rng = Rng::new(17);
        let x = gen_matrix(&mut rng, 50, 1, -3.0, 3.0);
        let y: Vec<f64> = (0..50).map(|i| (x.row(i)[0]).sin()).collect();
        let opt = HyperOpt { restarts: 2, max_evals: 40, ..Default::default() };
        let model = opt.fit(x.clone(), &y).unwrap();
        let extreme = OrdinaryKriging::fit(
            x.clone(),
            &y,
            Kernel::se_isotropic(1, 1e2),
            1e-8,
        )
        .unwrap();
        assert!(model.nll() <= extreme.nll() + 1e-9);
        // The optimized model should interpolate well.
        let pred = model.predict(&x).unwrap();
        let max_err = pred
            .mean
            .iter()
            .zip(&y)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(max_err < 1e-2, "max_err {max_err}");
    }

    #[test]
    fn isotropic_mode_searches_one_dim() {
        let mut rng = Rng::new(23);
        let x = gen_matrix(&mut rng, 30, 3, -1.0, 1.0);
        let y: Vec<f64> = (0..30).map(|i| x.row(i).iter().sum::<f64>()).collect();
        let opt = HyperOpt { isotropic: true, restarts: 1, max_evals: 25, ..Default::default() };
        let model = opt.fit(x, &y).unwrap();
        let t = model.kernel().theta.clone();
        assert!(t.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-12), "not isotropic: {t:?}");
    }

    #[test]
    fn cached_search_deterministic_across_workers() {
        // The cached objective is engineered to be worker-count
        // independent: same data + seed → bit-identical model.
        let mut rng = Rng::new(41);
        let x = gen_matrix(&mut rng, 40, 2, -2.0, 2.0);
        let y: Vec<f64> = (0..40).map(|i| (1.3 * x.row(i)[0]).sin()).collect();
        let base = HyperOpt { restarts: 2, max_evals: 25, ..Default::default() };
        let serial = base.fit(x.clone(), &y).unwrap();
        let parallel = HyperOpt { assembly_workers: Some(4), ..base }
            .fit(x.clone(), &y)
            .unwrap();
        assert_eq!(serial.nll().to_bits(), parallel.nll().to_bits());
        assert_eq!(serial.kernel().theta, parallel.kernel().theta);
    }

    #[test]
    fn fit_shared_takes_no_copy() {
        // The Arc handed to fit_shared is the buffer the model keeps.
        let mut rng = Rng::new(43);
        let x = std::sync::Arc::new(gen_matrix(&mut rng, 25, 1, -2.0, 2.0));
        let y: Vec<f64> = (0..25).map(|i| x.row(i)[0]).collect();
        let opt = HyperOpt { restarts: 1, max_evals: 10, ..Default::default() };
        let model = opt.fit_shared(std::sync::Arc::clone(&x), &y).unwrap();
        assert!(std::ptr::eq(model.x_train(), x.as_ref()));
    }

    #[test]
    fn nugget_estimation_recovers_noise_regime() {
        // Noisy data: estimated nugget should exceed the tiny default.
        let mut rng = Rng::new(31);
        let x = gen_matrix(&mut rng, 60, 1, -3.0, 3.0);
        let y: Vec<f64> =
            (0..60).map(|i| x.row(i)[0].sin() + rng.normal_with(0.0, 0.5)).collect();
        let opt = HyperOpt {
            nugget: NuggetMode::Estimate { log_bounds: (-8.0, 1.0) },
            restarts: 2,
            max_evals: 60,
            ..Default::default()
        };
        let model = opt.fit(x, &y).unwrap();
        assert!(model.nugget() > 1e-4, "nugget {} too small for noisy data", model.nugget());
    }
}
