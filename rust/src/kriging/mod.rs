//! Ordinary Kriging (Gaussian process regression) — the per-cluster model.
//!
//! [`model::OrdinaryKriging`] implements paper Eq. 3–5 with concentrated
//! trend/variance estimates; [`hyperopt::HyperOpt`] performs the ML
//! hyper-parameter search. The [`Surrogate`] trait is the common predict
//! interface shared by plain Kriging, the Cluster-Kriging flavors and all
//! baselines, so the evaluation harness treats every algorithm uniformly.

pub mod hyperopt;
pub mod model;

pub use hyperopt::{HyperOpt, NuggetMode};
pub use model::{KrigingError, OrdinaryKriging, Prediction};

use crate::util::matrix::Matrix;

/// Anything that predicts a posterior mean + variance for a batch of
/// points. Implemented by `OrdinaryKriging`, every Cluster-Kriging flavor
/// and the baselines (SoD, FITC, BCM).
pub trait Surrogate: Send + Sync {
    /// Posterior mean and variance per row of `xt`.
    fn predict(&self, xt: &Matrix) -> anyhow::Result<Prediction>;

    /// Human-readable algorithm name (for reports).
    fn name(&self) -> &str;
}

impl Surrogate for OrdinaryKriging {
    fn predict(&self, xt: &Matrix) -> anyhow::Result<Prediction> {
        Ok(OrdinaryKriging::predict(self, xt)?)
    }

    fn name(&self) -> &str {
        "Kriging"
    }
}
