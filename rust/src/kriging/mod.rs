//! Ordinary Kriging (Gaussian process regression) — the per-cluster model.
//!
//! [`model::OrdinaryKriging`] implements paper Eq. 3–5 with concentrated
//! trend/variance estimates; [`hyperopt::HyperOpt`] performs the ML
//! hyper-parameter search. The [`Surrogate`] trait is the common model
//! lifecycle interface shared by plain Kriging, the Cluster-Kriging
//! flavors and all baselines: batch prediction (allocating and
//! buffer-reusing forms), input dimensionality, and artifact
//! serialization — so the evaluation harness, the serving coordinator and
//! the CLI treat every algorithm uniformly.

pub mod hyperopt;
pub mod model;

pub use hyperopt::{HyperOpt, NuggetMode};
pub use model::{KrigingError, OrdinaryKriging, Prediction};

use crate::util::matrix::Matrix;

/// Anything that predicts a posterior mean + variance for a batch of
/// points. Implemented by `OrdinaryKriging`, every Cluster-Kriging flavor
/// and the baselines (SoD, FITC, BCM).
pub trait Surrogate: Send + Sync {
    /// Posterior mean and variance per row of `xt`.
    fn predict(&self, xt: &Matrix) -> anyhow::Result<Prediction>;

    /// Human-readable algorithm name (for reports).
    fn name(&self) -> &str;

    /// Input dimensionality the model expects (columns of `xt`).
    fn dim(&self) -> usize;

    /// [`Self::predict`] into caller-provided buffers — the serving hot
    /// path, where the [`crate::coordinator::Batcher`] reuses one pair of
    /// buffers across flushes instead of allocating per batch. `mean` and
    /// `variance` must each hold exactly `xt.rows()` elements.
    ///
    /// The default implementation routes through [`Self::predict`] (one
    /// allocation per call); the hot-path models override it.
    fn predict_into(
        &self,
        xt: &Matrix,
        mean: &mut [f64],
        variance: &mut [f64],
    ) -> anyhow::Result<()> {
        assert_eq!(mean.len(), xt.rows(), "predict_into: mean buffer size");
        assert_eq!(variance.len(), xt.rows(), "predict_into: variance buffer size");
        let pred = self.predict(xt)?;
        mean.copy_from_slice(&pred.mean);
        variance.copy_from_slice(&pred.variance);
        Ok(())
    }

    /// Serialize the fitted model as a versioned binary artifact (see
    /// [`crate::surrogate::artifact`]). Load it back with
    /// [`crate::surrogate::SurrogateSpec::load`]. Models that cannot be
    /// persisted (test doubles, experimental wrappers) keep the default,
    /// which is a recoverable error.
    fn save(&self, _w: &mut dyn std::io::Write) -> anyhow::Result<()> {
        anyhow::bail!("{} does not support artifact serialization", self.name())
    }

    /// Shared online-learning view, for models that can absorb new
    /// observations at serve time ([`crate::online::OnlineSurrogate`]):
    /// Ordinary Kriging, the Cluster Kriging flavors, SoD, and
    /// [`crate::surrogate::Standardized`] around any of them. The default
    /// (`None`) marks the model fit-once (FITC, BCM, test doubles).
    /// Implementations must answer consistently with
    /// [`Self::as_online_mut`].
    fn as_online(&self) -> Option<&dyn crate::online::OnlineSurrogate> {
        None
    }

    /// Mutable counterpart of [`Self::as_online`] — the handle
    /// `observe`/`observe_batch` mutate through.
    fn as_online_mut(&mut self) -> Option<&mut dyn crate::online::OnlineSurrogate> {
        None
    }

    /// Shared (interior-mutability) observation endpoint, implemented by
    /// the serving adapter [`crate::online::OnlineModel`] so the
    /// coordinator can stream observations into an `Arc<dyn Surrogate>`
    /// registry slot. Plain fitted models keep the default `None`; mutate
    /// those through [`Self::as_online_mut`] instead.
    fn observer(&self) -> Option<&dyn crate::online::OnlineObserver> {
        None
    }

    /// Raw per-cluster posterior view for distributed serving (protocol
    /// v5 `spredict`): models that decompose into per-cluster Kriging
    /// posteriors — [`crate::cluster_kriging::ClusterKriging`], the
    /// split-off [`crate::distributed::ClusterShard`], and the wrappers
    /// around either — expose them here so a shard worker can serve
    /// *uncombined* `ClusterPrediction`s for a scatter-gather coordinator
    /// to merge. The default `None` marks models with no cluster
    /// decomposition (plain Kriging, SoD, FITC, BCM, doubles).
    fn shard_predictor(&self) -> Option<&dyn crate::distributed::ShardPredictor> {
        None
    }

    /// Per-cluster numerical-health report: condition estimates,
    /// escalated jitter, and points per cluster, as probed at fit time
    /// (or lazily, off the request path — implementations may run an
    /// O(n²) estimate per cluster). Consumed by `ckrig doctor`, the
    /// `metricsx` exposition, and the shard handshake. The default
    /// `None` marks models with no Kriging factor to probe (baselines,
    /// test doubles).
    fn health_report(&self) -> Option<crate::obs::health::HealthReport> {
        None
    }
}

impl Surrogate for OrdinaryKriging {
    fn predict(&self, xt: &Matrix) -> anyhow::Result<Prediction> {
        Ok(OrdinaryKriging::predict(self, xt)?)
    }

    fn name(&self) -> &str {
        "Kriging"
    }

    fn dim(&self) -> usize {
        self.kernel().dim()
    }

    fn predict_into(
        &self,
        xt: &Matrix,
        mean: &mut [f64],
        variance: &mut [f64],
    ) -> anyhow::Result<()> {
        OrdinaryKriging::predict_into_with_workers(
            self,
            xt,
            crate::util::threadpool::default_workers(),
            mean,
            variance,
        )?;
        Ok(())
    }

    fn save(&self, w: &mut dyn std::io::Write) -> anyhow::Result<()> {
        let mut payload = crate::util::binio::BinWriter::new();
        self.write_artifact(&mut payload);
        crate::surrogate::artifact::write_model(
            w,
            crate::surrogate::artifact::TAG_KRIGING,
            &payload.into_bytes(),
        )
    }

    fn as_online(&self) -> Option<&dyn crate::online::OnlineSurrogate> {
        Some(self)
    }

    fn as_online_mut(&mut self) -> Option<&mut dyn crate::online::OnlineSurrogate> {
        Some(self)
    }

    fn health_report(&self) -> Option<crate::obs::health::HealthReport> {
        Some(crate::obs::health::HealthReport::single(self.health_or_probe()))
    }
}

impl crate::online::OnlineSurrogate for OrdinaryKriging {
    fn observe(&mut self, x: &[f64], y: f64) -> anyhow::Result<()> {
        Ok(self.observe_point(x, y)?)
    }

    fn training_snapshot(&self) -> (Matrix, Vec<f64>) {
        (self.x_train().clone(), self.y_train().to_vec())
    }

    fn training_len(&self) -> usize {
        self.n_train()
    }

    fn resident_bytes(&self) -> usize {
        OrdinaryKriging::resident_bytes(self)
    }

    fn forget_oldest(&mut self) -> anyhow::Result<bool> {
        // `observe` appends, so row 0 is always the oldest point.
        if self.n_train() <= 1 {
            return Ok(false);
        }
        self.forget_point(0)?;
        Ok(true)
    }
}
