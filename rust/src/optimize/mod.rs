//! Bayesian optimization engine: the **optimize** stage of the model
//! lifecycle (spec → fit → serve → observe → optimize).
//!
//! The paper's introduction motivates Cluster Kriging as a surrogate in
//! *expensive black-box optimization* — the Kriging variance is the
//! exploration signal. This module makes that workload first-class over
//! any `Box<dyn Surrogate>`:
//!
//! * [`acquisition`] — Expected Improvement, Probability of Improvement
//!   and the Lower Confidence Bound, vectorized over candidate batches
//!   through `predict_into` with the shared erf-based normal CDF
//!   ([`crate::util::stats::norm_cdf`], A&S 7.1.26, ~1.5e-7);
//!   minimization convention.
//! * [`candidates`] — box [`Bounds`], per-dimension Latin-hypercube
//!   pools, and bounds-clipped Gaussian perturbation clouds around the
//!   incumbent.
//! * [`driver`] — the [`Optimizer`] `ask(q)`/`tell` loop: constant-liar
//!   fantasization for batch proposals, O(n_c²) incremental absorption of
//!   tells through [`crate::online::OnlineSurrogate::observe`] when the
//!   surrogate supports it, refit fallback otherwise, and full
//!   θ-refreshing refits scheduled by the serving stack's
//!   [`crate::online::OnlinePolicy`] engine.
//!
//! The serving coordinator exposes the same capability over the wire as
//! protocol v4: `suggest [model] q [bounds]` proposes candidates from a
//! live slot's posterior and `tell` streams evaluations back through the
//! observe flush queue (see [`crate::coordinator`]), turning any served
//! model into optimization-as-a-service.

pub mod acquisition;
pub mod candidates;
pub mod driver;

pub use acquisition::Acquisition;
pub use candidates::{candidate_pool, latin_hypercube_in, Bounds};
pub use driver::{Optimizer, OptimizerConfig, OptimizerStats};

use crate::kriging::Surrogate;
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;
use anyhow::Result;

/// One-shot, non-mutating batch proposal from a *shared* fitted model —
/// the serving coordinator's `suggest` path, where the slot's model is
/// behind an `Arc` and must not absorb constant-liar lies. Greedy
/// selection with an exclusion radius stands in for fantasization: after
/// each pick, candidates within `min_dist` (a fraction of the box
/// diagonal) are suppressed so the batch still spreads.
///
/// `best` is the incumbent value (smallest observed target) and
/// `incumbent` its location, both typically read off the slot's training
/// snapshot. Every returned row lies inside `bounds`.
pub fn propose(
    model: &dyn Surrogate,
    bounds: &Bounds,
    best: f64,
    incumbent: Option<&[f64]>,
    q: usize,
    acquisition: Acquisition,
    pool: usize,
    rng: &mut Rng,
) -> Result<Matrix> {
    anyhow::ensure!(q >= 1, "propose: q must be ≥ 1");
    anyhow::ensure!(
        model.dim() == bounds.dim(),
        "propose: model expects {} dims but bounds have {}",
        model.dim(),
        bounds.dim()
    );
    let d = bounds.dim();
    // One pool, one batched posterior call, shared by all q picks.
    let pool_n = pool.max(q);
    let cands = candidate_pool(bounds, incumbent, pool_n, pool_n / 16, 0.05, rng);
    let mut mean = Vec::new();
    let mut var = Vec::new();
    let mut scores = Vec::new();
    acquisition.score_batch_into(model, &cands, best, &mut mean, &mut var, &mut scores)?;
    // Exclusion radius: 5% of the box diagonal.
    let diag: f64 = (0..d)
        .map(|j| {
            let r = bounds.hi()[j] - bounds.lo()[j];
            r * r
        })
        .sum::<f64>()
        .sqrt();
    let min_dist = 0.05 * diag;
    let mut out = Vec::with_capacity(q * d);
    let mut taken = 0;
    while taken < q {
        let pick = crate::util::stats::argmax(&scores);
        if scores[pick] == f64::NEG_INFINITY {
            // Pool exhausted by exclusion (tiny pools / large q): relax
            // the radius by re-scoring what's left.
            acquisition
                .score_batch_into(model, &cands, best, &mut mean, &mut var, &mut scores)?;
            for t in 0..taken {
                let row = &out[t * d..(t + 1) * d];
                for i in 0..cands.rows() {
                    if crate::util::stats::dist(cands.row(i), row) < 1e-12 {
                        scores[i] = f64::NEG_INFINITY;
                    }
                }
            }
            let pick = crate::util::stats::argmax(&scores);
            out.extend_from_slice(cands.row(pick));
            scores[pick] = f64::NEG_INFINITY;
            taken += 1;
            continue;
        }
        out.extend_from_slice(cands.row(pick));
        taken += 1;
        // Suppress the picked candidate and its neighborhood.
        for i in 0..cands.rows() {
            if scores[i] != f64::NEG_INFINITY
                && crate::util::stats::dist(cands.row(i), cands.row(pick)) < min_dist
            {
                scores[i] = f64::NEG_INFINITY;
            }
        }
    }
    Ok(Matrix::from_vec(q, d, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kriging::Prediction;

    /// Quadratic-bowl posterior double: mean = ‖x‖², constant variance.
    struct Bowl {
        d: usize,
    }
    impl Surrogate for Bowl {
        fn predict(&self, xt: &Matrix) -> Result<Prediction> {
            Ok(Prediction {
                mean: (0..xt.rows())
                    .map(|i| xt.row(i).iter().map(|v| v * v).sum())
                    .collect(),
                variance: vec![0.5; xt.rows()],
            })
        }
        fn name(&self) -> &str {
            "bowl"
        }
        fn dim(&self) -> usize {
            self.d
        }
    }

    #[test]
    fn propose_returns_q_distinct_in_bounds_points() {
        let bounds = Bounds::cube(2, -2.0, 2.0).unwrap();
        let model = Bowl { d: 2 };
        let mut rng = Rng::new(5);
        let got = propose(
            &model,
            &bounds,
            4.0,
            Some(&[0.1, 0.1]),
            4,
            Acquisition::ei(),
            256,
            &mut rng,
        )
        .unwrap();
        assert_eq!(got.shape(), (4, 2));
        for i in 0..4 {
            assert!(bounds.contains(got.row(i)), "row {i} out of bounds");
            for j in (i + 1)..4 {
                assert!(
                    crate::util::stats::dist(got.row(i), got.row(j)) > 1e-9,
                    "rows {i} and {j} coincide"
                );
            }
        }
        // The bowl's minimum is at the origin; the best proposal should
        // sit well inside the low-mean region.
        let best_row = (0..4)
            .min_by(|&a, &b| {
                let na: f64 = got.row(a).iter().map(|v| v * v).sum();
                let nb: f64 = got.row(b).iter().map(|v| v * v).sum();
                na.partial_cmp(&nb).unwrap()
            })
            .unwrap();
        let norm: f64 = got.row(best_row).iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(norm < 1.0, "no proposal near the bowl minimum (‖x‖ = {norm})");
    }

    #[test]
    fn propose_validates_and_exhausts_gracefully() {
        let bounds = Bounds::cube(2, 0.0, 1.0).unwrap();
        let model = Bowl { d: 2 };
        let mut rng = Rng::new(9);
        assert!(propose(&model, &bounds, 0.0, None, 0, Acquisition::ei(), 64, &mut rng)
            .is_err());
        let wrong = Bowl { d: 3 };
        assert!(propose(&wrong, &bounds, 0.0, None, 1, Acquisition::ei(), 64, &mut rng)
            .is_err());
        // q close to the pool size forces the exclusion-relax path.
        let got =
            propose(&model, &bounds, 1.0, None, 6, Acquisition::lcb(), 6, &mut rng).unwrap();
        assert_eq!(got.rows(), 6);
        for i in 0..6 {
            assert!(bounds.contains(got.row(i)));
        }
    }
}
