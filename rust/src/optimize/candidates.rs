//! Candidate generation for the proposal loop: box bounds, per-dimension
//! Latin-hypercube pools, and Gaussian perturbation clouds around the
//! incumbent.
//!
//! The EGO inner problem — maximize the acquisition over the box — is
//! solved by dense candidate scoring (one batched `predict_into` over the
//! pool), which plays to the serving stack's strength: the same vectorized
//! posterior path that answers `predictb` scores 10k candidates in one
//! call. The pool mixes a space-filling LHS layer (global exploration)
//! with a cloud of bounds-clipped Gaussian perturbations around the
//! incumbent (local refinement), the textbook hybrid.

use crate::util::matrix::Matrix;
use crate::util::rng::Rng;
use anyhow::{Context, Result};

/// An axis-aligned search box `[lo_j, hi_j]` per dimension.
#[derive(Debug, Clone, PartialEq)]
pub struct Bounds {
    lo: Vec<f64>,
    hi: Vec<f64>,
}

impl Bounds {
    /// Per-dimension box; every pair must be finite with `lo < hi`.
    pub fn new(lo: Vec<f64>, hi: Vec<f64>) -> Result<Self> {
        anyhow::ensure!(!lo.is_empty(), "bounds need at least one dimension");
        anyhow::ensure!(
            lo.len() == hi.len(),
            "bounds dimension mismatch: {} lows vs {} highs",
            lo.len(),
            hi.len()
        );
        for j in 0..lo.len() {
            anyhow::ensure!(
                lo[j].is_finite() && hi[j].is_finite() && lo[j] < hi[j],
                "bad bounds for dimension {j}: [{}, {}]",
                lo[j],
                hi[j]
            );
        }
        Ok(Self { lo, hi })
    }

    /// The same `[lo, hi]` interval in every one of `d` dimensions (the
    /// benchmark functions' canonical domains are cubes).
    pub fn cube(d: usize, lo: f64, hi: f64) -> Result<Self> {
        Self::new(vec![lo; d], vec![hi; d])
    }

    /// Per-column min/max of a data matrix, expanded by `margin` × range
    /// on each side — the bounds a served model infers from its training
    /// snapshot when the client doesn't send any. Collapsed columns
    /// (constant features) get a unit box around the value.
    pub fn from_data(x: &Matrix, margin: f64) -> Result<Self> {
        anyhow::ensure!(x.rows() > 0, "cannot infer bounds from an empty matrix");
        let d = x.cols();
        let mut lo = vec![f64::INFINITY; d];
        let mut hi = vec![f64::NEG_INFINITY; d];
        for i in 0..x.rows() {
            let row = x.row(i);
            for j in 0..d {
                lo[j] = lo[j].min(row[j]);
                hi[j] = hi[j].max(row[j]);
            }
        }
        for j in 0..d {
            let range = hi[j] - lo[j];
            if range <= 0.0 {
                lo[j] -= 0.5;
                hi[j] += 0.5;
            } else {
                lo[j] -= margin * range;
                hi[j] += margin * range;
            }
        }
        Self::new(lo, hi)
    }

    /// Parse the wire form `lo1,hi1;lo2,hi2;…` (one pair per dimension),
    /// as carried by the `suggest` protocol op.
    pub fn parse(s: &str) -> Result<Self> {
        let mut lo = Vec::new();
        let mut hi = Vec::new();
        for (j, pair) in s.split(';').enumerate() {
            let (a, b) = pair
                .split_once(',')
                .with_context(|| format!("bounds pair {} is not lo,hi", j + 1))?;
            lo.push(a.trim().parse::<f64>().with_context(|| format!("bad low {a:?}"))?);
            hi.push(b.trim().parse::<f64>().with_context(|| format!("bad high {b:?}"))?);
        }
        Self::new(lo, hi)
    }

    pub fn dim(&self) -> usize {
        self.lo.len()
    }

    pub fn lo(&self) -> &[f64] {
        &self.lo
    }

    pub fn hi(&self) -> &[f64] {
        &self.hi
    }

    /// Clip a point into the box, coordinate-wise.
    pub fn clip(&self, x: &mut [f64]) {
        debug_assert_eq!(x.len(), self.dim());
        for j in 0..x.len() {
            x[j] = x[j].clamp(self.lo[j], self.hi[j]);
        }
    }

    /// Whether the point lies inside the (closed) box.
    pub fn contains(&self, x: &[f64]) -> bool {
        x.len() == self.dim()
            && (0..x.len()).all(|j| x[j] >= self.lo[j] && x[j] <= self.hi[j])
    }
}

impl std::fmt::Display for Bounds {
    /// Inverse of [`Bounds::parse`].
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for j in 0..self.dim() {
            if j > 0 {
                write!(f, ";")?;
            }
            write!(f, "{},{}", self.lo[j], self.hi[j])?;
        }
        Ok(())
    }
}

/// Latin hypercube sample of `n` points in the box: per dimension, one
/// point per stratum in a shuffled order — space-filling marginals in
/// every coordinate. Generalizes `data::synthetic::latin_hypercube` to
/// per-dimension bounds and a caller-owned RNG stream.
pub fn latin_hypercube_in(bounds: &Bounds, n: usize, rng: &mut Rng) -> Matrix {
    let d = bounds.dim();
    let mut x = Matrix::zeros(n, d);
    if n == 0 {
        return x;
    }
    let mut strata: Vec<usize> = (0..n).collect();
    for j in 0..d {
        let width = (bounds.hi[j] - bounds.lo[j]) / n as f64;
        rng.shuffle(&mut strata);
        for i in 0..n {
            x[(i, j)] = bounds.lo[j] + (strata[i] as f64 + rng.uniform()) * width;
        }
    }
    x
}

/// Build a proposal candidate pool of `pool` rows: a space-filling LHS
/// layer plus (when an incumbent is known) `local` rows drawn from a
/// Gaussian around it with per-dimension σ = `sigma_frac` × range,
/// clipped into the box. Every row is guaranteed inside `bounds`.
pub fn candidate_pool(
    bounds: &Bounds,
    incumbent: Option<&[f64]>,
    pool: usize,
    local: usize,
    sigma_frac: f64,
    rng: &mut Rng,
) -> Matrix {
    let d = bounds.dim();
    let local = match incumbent {
        Some(_) => local.min(pool.saturating_sub(1)),
        None => 0,
    };
    let mut x = latin_hypercube_in(bounds, pool, rng);
    if let Some(inc) = incumbent {
        debug_assert_eq!(inc.len(), d, "incumbent dimension mismatch");
        // Overwrite the first `local` LHS rows with the perturbation
        // cloud; at least one global row always survives.
        for i in 0..local {
            let row = x.row_mut(i);
            for j in 0..d {
                let sd = sigma_frac * (bounds.hi[j] - bounds.lo[j]);
                row[j] = (inc[j] + rng.normal_with(0.0, sd)).clamp(bounds.lo[j], bounds.hi[j]);
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check_default, gen_size};

    #[test]
    fn bounds_validate() {
        assert!(Bounds::new(vec![0.0], vec![1.0]).is_ok());
        assert!(Bounds::new(vec![], vec![]).is_err());
        assert!(Bounds::new(vec![0.0, 0.0], vec![1.0]).is_err());
        assert!(Bounds::new(vec![1.0], vec![1.0]).is_err(), "lo == hi");
        assert!(Bounds::new(vec![2.0], vec![1.0]).is_err(), "inverted");
        assert!(Bounds::new(vec![f64::NAN], vec![1.0]).is_err());
        assert!(Bounds::cube(3, -1.0, 1.0).unwrap().dim() == 3);
    }

    #[test]
    fn clip_and_contains() {
        let b = Bounds::new(vec![-1.0, 0.0], vec![1.0, 2.0]).unwrap();
        assert!(b.contains(&[0.0, 1.0]));
        assert!(b.contains(&[-1.0, 2.0]), "boundary is inside");
        assert!(!b.contains(&[1.5, 1.0]));
        assert!(!b.contains(&[0.0]), "wrong dimension");
        let mut p = [3.0, -4.0];
        b.clip(&mut p);
        assert_eq!(p, [1.0, 0.0]);
    }

    #[test]
    fn parse_display_roundtrip() {
        let b = Bounds::new(vec![-6.0, 0.5], vec![6.0, 2.5]).unwrap();
        let text = b.to_string();
        assert_eq!(text, "-6,6;0.5,2.5");
        assert_eq!(Bounds::parse(&text).unwrap(), b);
        assert!(Bounds::parse("1;2").is_err(), "missing comma");
        assert!(Bounds::parse("2,1").is_err(), "inverted");
        assert!(Bounds::parse("a,b").is_err());
    }

    #[test]
    fn from_data_covers_columns() {
        let x = Matrix::from_vec(3, 2, vec![0.0, 5.0, 2.0, 5.0, 1.0, 5.0]);
        let b = Bounds::from_data(&x, 0.1).unwrap();
        // Column 0 spans [0, 2] with 10% margin; column 1 is constant and
        // gets a unit box.
        assert!((b.lo()[0] - -0.2).abs() < 1e-12);
        assert!((b.hi()[0] - 2.2).abs() < 1e-12);
        assert_eq!(b.lo()[1], 4.5);
        assert_eq!(b.hi()[1], 5.5);
        for i in 0..3 {
            assert!(b.contains(x.row(i)));
        }
    }

    #[test]
    fn lhs_is_stratified_per_dimension() {
        let b = Bounds::new(vec![0.0, -10.0], vec![1.0, 10.0]).unwrap();
        let n = 16;
        let mut rng = Rng::new(3);
        let x = latin_hypercube_in(&b, n, &mut rng);
        for j in 0..2 {
            let width = (b.hi()[j] - b.lo()[j]) / n as f64;
            let mut hit = vec![false; n];
            for i in 0..n {
                let s = ((x[(i, j)] - b.lo()[j]) / width).floor() as usize;
                hit[s.min(n - 1)] = true;
            }
            assert!(hit.iter().all(|&h| h), "dimension {j} missed a stratum");
        }
    }

    #[test]
    fn pool_rows_always_inside_bounds_prop() {
        check_default(|rng| {
            let d = gen_size(rng, 1, 5);
            let lo: Vec<f64> = (0..d).map(|_| rng.uniform_in(-10.0, 0.0)).collect();
            let hi: Vec<f64> = lo.iter().map(|&l| l + rng.uniform_in(0.1, 20.0)).collect();
            let b = Bounds::new(lo, hi).map_err(|e| e.to_string())?;
            let inc: Vec<f64> = (0..d)
                .map(|j| rng.uniform_in(b.lo()[j], b.hi()[j]))
                .collect();
            let pool = gen_size(rng, 1, 64);
            let local = gen_size(rng, 0, 32);
            let x = candidate_pool(&b, Some(&inc), pool, local, 0.3, rng);
            crate::prop_assert!(x.rows() == pool);
            for i in 0..x.rows() {
                crate::prop_assert!(
                    b.contains(x.row(i)),
                    "row {i} escaped the box: {:?}",
                    x.row(i)
                );
            }
            Ok(())
        });
    }

    #[test]
    fn pool_keeps_a_global_row() {
        // Even with local ≥ pool, one LHS row survives for exploration.
        let b = Bounds::cube(2, 0.0, 1.0).unwrap();
        let mut rng = Rng::new(11);
        let x = candidate_pool(&b, Some(&[0.5, 0.5]), 8, 100, 0.01, &mut rng);
        assert_eq!(x.rows(), 8);
        // Rows 0..=6 cluster near the incumbent (σ = 1%); the last row is
        // untouched LHS and lands in its stratum anywhere in the box.
        let far = (0..8).filter(|&i| {
            let r = x.row(i);
            (r[0] - 0.5).abs() > 0.2 || (r[1] - 0.5).abs() > 0.2
        });
        assert!(far.count() <= 1, "perturbation cloud too diffuse");
    }

    #[test]
    fn deterministic_for_same_seed() {
        let b = Bounds::cube(3, -2.0, 2.0).unwrap();
        let a = candidate_pool(&b, Some(&[0.0; 3]), 32, 8, 0.1, &mut Rng::new(7));
        let c = candidate_pool(&b, Some(&[0.0; 3]), 32, 8, 0.1, &mut Rng::new(7));
        assert_eq!(a.max_abs_diff(&c), 0.0);
    }
}
