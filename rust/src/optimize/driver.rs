//! The ask/tell optimization driver: sequential model-based (EGO-style)
//! minimization over any [`SurrogateSpec`]-fittable surrogate.
//!
//! [`Optimizer`] owns the raw-unit evaluation history and the surrogate's
//! lifecycle; the caller owns the expensive black box:
//!
//! ```text
//! let xs = opt.ask(q)?;            // q proposals (constant-liar batch)
//! for each row x: y = f(x);
//! opt.tell(&x, y)?;                // absorb — O(n_c²) when online
//! ```
//!
//! `tell` composes with the online subsystem end to end: when the fitted
//! model is online-capable (Ordinary Kriging, every Cluster Kriging
//! flavor, SoD — through the [`Standardized`] wrapper), each told point
//! is an incremental [`OnlineSurrogate::observe`] under fixed
//! hyper-parameters instead of a refit; fit-once models (FITC, BCM) fall
//! back to a lazy refit before the next proposal. *When* the fixed-θ
//! incremental path stops being enough is judged by the same
//! [`OnlinePolicy`] engine serving uses: the staleness budget and the
//! rolling drift monitor (standardized pre-update residuals) schedule a
//! full refit with a fresh hyper-parameter search.
//!
//! Batch proposals (`ask(q)` with q > 1) use **constant-liar
//! fantasization** (Ginsbourger et al. 2010): after each pick the model
//! absorbs the *lie* `y = best-so-far` at the picked point, so the next
//! pick's acquisition sees deflated variance there and spreads the batch;
//! the fantasies mark the model dirty, and the first subsequent `tell` or
//! `ask` replaces it with a truth-only fit.
//!
//! [`OnlineSurrogate::observe`]: crate::online::OnlineSurrogate::observe
//! [`Standardized`]: crate::surrogate::Standardized

use crate::data::{Dataset, Standardizer};
use crate::kriging::Surrogate;
use crate::online::policy::{DriftMonitor, OnlinePolicy};
use crate::optimize::acquisition::Acquisition;
use crate::optimize::candidates::{candidate_pool, latin_hypercube_in, Bounds};
use crate::surrogate::{FitOptions, Standardized, SurrogateSpec};
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;
use crate::util::stats::argmax;
use anyhow::{Context, Result};

/// Everything an [`Optimizer`] needs besides the search box.
#[derive(Debug, Clone)]
pub struct OptimizerConfig {
    /// Which surrogate to fit over the evaluation history.
    pub spec: SurrogateSpec,
    /// Budget for every (re)fit's hyper-parameter search.
    pub fit: FitOptions,
    /// Acquisition function maximized by each proposal.
    pub acquisition: Acquisition,
    /// Candidate pool size per proposal (one batched posterior call).
    pub pool: usize,
    /// How many pool rows are Gaussian perturbations of the incumbent.
    pub local: usize,
    /// Perturbation σ as a fraction of each dimension's range.
    pub local_sigma: f64,
    /// Space-filling design size before model-based proposals start.
    pub init: usize,
    /// When to replace the incremental fixed-θ path with a full refit
    /// (fresh hyper-parameter search) — the serving stack's policy
    /// engine, reused verbatim.
    pub policy: OnlinePolicy,
    /// Seed for the proposal RNG (candidate pools, initial design).
    pub seed: u64,
    /// Optional fit-path telemetry: one event per `tell` (observed
    /// value, incumbent, acquisition score of the proposal) plus refit
    /// phases and — via the fit options — per-eval hyperopt traces
    /// (see [`crate::obs::fitlog`]). Recording never perturbs the
    /// seeded proposal stream.
    pub telemetry: Option<crate::obs::FitSink>,
}

impl OptimizerConfig {
    /// Defaults tuned for expensive objectives: 512-candidate pools with
    /// a 32-point incumbent cloud, EI, a 2-point-per-dimension-ish
    /// initial design floor of 8, and a 16-observation staleness budget —
    /// a θ re-search every 16 evaluations is noise next to a black-box
    /// evaluation, and fresh length-scales matter as the search narrows.
    pub fn new(spec: SurrogateSpec) -> Self {
        Self {
            spec,
            fit: FitOptions::fast(),
            acquisition: Acquisition::ei(),
            pool: 512,
            local: 32,
            local_sigma: 0.05,
            init: 8,
            policy: OnlinePolicy { staleness_budget: 16, ..OnlinePolicy::default() },
            seed: 0x0B97,
            telemetry: None,
        }
    }
}

/// Driver counters (diagnostics / tests).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OptimizerStats {
    /// Proposals handed out by [`Optimizer::ask`].
    pub proposed: u64,
    /// Evaluations absorbed by [`Optimizer::tell`].
    pub told: u64,
    /// Tells absorbed through the incremental `observe` path.
    pub incremental: u64,
    /// Full surrogate (re)fits, the initial fit included.
    pub fits: u64,
}

/// Ask/tell sequential model-based optimizer (minimization).
pub struct Optimizer {
    bounds: Bounds,
    cfg: OptimizerConfig,
    rng: Rng,
    /// Row-major raw-unit evaluation history.
    x: Vec<f64>,
    y: Vec<f64>,
    /// The current surrogate ([`Standardized`] over the spec's model, so
    /// it speaks raw units); `None` until first fitted or when marked
    /// stale for a lazy refit.
    model: Option<Box<dyn Surrogate>>,
    /// Queue of not-yet-proposed initial-design rows (row-major). The
    /// whole remaining design phase is generated as *one* stratified LHS
    /// block and handed out row by row, so sequential `ask(1)` calls
    /// still walk a space-filling design rather than i.i.d. uniforms.
    design: Vec<f64>,
    /// Constant-liar lies currently absorbed into `model` (> 0 ⇒ the
    /// model is fantasy-laden and must be refitted before reuse).
    fantasies: usize,
    /// Raw-unit lies of the in-flight batch. Online surrogates carry them
    /// inside the model; the refit fallback re-derives each fantasy fit
    /// from history ∪ these, so earlier lies of the same batch survive.
    fantasy_x: Vec<f64>,
    fantasy_y: Vec<f64>,
    since_refit: usize,
    drift: DriftMonitor,
    stats: OptimizerStats,
    // Acquisition score of the most recent proposal; consumed by the
    // next `tell` so the telemetry row pairs the observed value with
    // the score that nominated it.
    last_acq: Option<f64>,
    // Scratch for the batched acquisition evaluation.
    mean_buf: Vec<f64>,
    var_buf: Vec<f64>,
    score_buf: Vec<f64>,
}

impl Optimizer {
    pub fn new(bounds: Bounds, cfg: OptimizerConfig) -> Result<Self> {
        anyhow::ensure!(cfg.pool >= 1, "candidate pool must be ≥ 1");
        anyhow::ensure!(cfg.init >= 2, "initial design needs ≥ 2 points");
        anyhow::ensure!(
            cfg.local_sigma.is_finite() && cfg.local_sigma > 0.0,
            "local_sigma must be positive"
        );
        let drift = DriftMonitor::new(cfg.policy.drift_window);
        let rng = Rng::new(cfg.seed);
        Ok(Self {
            bounds,
            cfg,
            rng,
            x: Vec::new(),
            y: Vec::new(),
            model: None,
            design: Vec::new(),
            fantasies: 0,
            fantasy_x: Vec::new(),
            fantasy_y: Vec::new(),
            since_refit: 0,
            drift,
            stats: OptimizerStats::default(),
            last_acq: None,
            mean_buf: Vec::new(),
            var_buf: Vec::new(),
            score_buf: Vec::new(),
        })
    }

    pub fn dim(&self) -> usize {
        self.bounds.dim()
    }

    pub fn bounds(&self) -> &Bounds {
        &self.bounds
    }

    /// Evaluations told so far.
    pub fn n_observed(&self) -> usize {
        self.y.len()
    }

    pub fn stats(&self) -> OptimizerStats {
        self.stats
    }

    /// The incumbent: best (lowest) observed evaluation, if any.
    pub fn best(&self) -> Option<(&[f64], f64)> {
        if self.y.is_empty() {
            return None;
        }
        let i = crate::util::stats::argmin(&self.y);
        let d = self.dim();
        Some((&self.x[i * d..(i + 1) * d], self.y[i]))
    }

    /// Absorb one evaluated point. Online-capable surrogates take it as
    /// an O(n_c²) incremental observe; otherwise (or after a policy
    /// trigger / a fantasy-laden batch) the model is dropped and lazily
    /// refitted at the next [`Self::ask`].
    pub fn tell(&mut self, x: &[f64], y: f64) -> Result<()> {
        anyhow::ensure!(
            x.len() == self.dim(),
            "tell: point has {} dims, optimizer expects {}",
            x.len(),
            self.dim()
        );
        anyhow::ensure!(
            y.is_finite() && x.iter().all(|v| v.is_finite()),
            "tell: non-finite evaluation"
        );
        if self.fantasies > 0 {
            // The model carries constant-liar lies from a batch ask; the
            // truth arriving now supersedes them.
            self.model = None;
            self.fantasies = 0;
            self.fantasy_x.clear();
            self.fantasy_y.clear();
        }
        let mut drop_model = false;
        if let Some(model) = &mut self.model {
            // Drift signal: standardized residual of the pre-update
            // posterior at the incoming point (same definition as the
            // serving adapter's monitor).
            let xt = Matrix::from_vec(1, x.len(), x.to_vec());
            let (mut m, mut v) = ([0.0], [0.0]);
            model.predict_into(&xt, &mut m, &mut v)?;
            self.drift.push((y - m[0]) / (v[0].max(0.0) + 1e-12).sqrt());
            match model.as_online_mut() {
                Some(online) => {
                    online.observe(x, y).context("incremental tell failed")?;
                    self.stats.incremental += 1;
                }
                // Fit-once surrogate: lazy refit before the next ask.
                None => drop_model = true,
            }
        }
        if drop_model {
            self.model = None;
        }
        self.x.extend_from_slice(x);
        self.y.push(y);
        self.stats.told += 1;
        self.since_refit += 1;
        if let Some(sink) = &self.cfg.telemetry {
            let best = self.y.iter().copied().fold(f64::INFINITY, f64::min);
            let acq = self.last_acq.take();
            sink.opt_iter(self.stats.told, y, best, acq);
        }
        if self.model.is_some() {
            if let Some(reason) = self.cfg.policy.should_refit(self.since_refit, &self.drift) {
                log::debug!("optimizer refit scheduled ({reason:?})");
                self.model = None;
            }
        }
        Ok(())
    }

    /// Propose `q ≥ 1` points to evaluate next. During the initial design
    /// phase (fewer than `cfg.init` tells) proposals are space-filling
    /// LHS rows; afterwards each is the acquisition argmax over a fresh
    /// candidate pool, with constant-liar fantasization between the picks
    /// of one batch. Every proposal lies inside the bounds.
    pub fn ask(&mut self, q: usize) -> Result<Matrix> {
        anyhow::ensure!(q >= 1, "ask: q must be ≥ 1");
        let d = self.dim();
        if self.y.len() < self.cfg.init {
            let mut out = Vec::with_capacity(q * d);
            let mut taken = 0;
            while taken < q {
                if self.design.len() < d {
                    // One stratified block covers the whole remaining
                    // design phase (at least the rest of this ask).
                    let block_n = (self.cfg.init - self.y.len()).max(q - taken);
                    self.design =
                        latin_hypercube_in(&self.bounds, block_n, &mut self.rng).into_vec();
                }
                out.extend(self.design.drain(..d));
                taken += 1;
            }
            self.stats.proposed += q as u64;
            return Ok(Matrix::from_vec(q, d, out));
        }
        if self.model.is_none() || self.fantasies > 0 {
            self.refit()?;
        }
        let best = self.y.iter().copied().fold(f64::INFINITY, f64::min);
        let inc = {
            let i = crate::util::stats::argmin(&self.y);
            self.x[i * d..(i + 1) * d].to_vec()
        };
        let mut out = Vec::with_capacity(q * d);
        for j in 0..q {
            let pool = candidate_pool(
                &self.bounds,
                Some(&inc),
                self.cfg.pool,
                self.cfg.local,
                self.cfg.local_sigma,
                &mut self.rng,
            );
            let model = self.model.as_ref().expect("fitted above");
            self.cfg.acquisition.score_batch_into(
                model.as_ref(),
                &pool,
                best,
                &mut self.mean_buf,
                &mut self.var_buf,
                &mut self.score_buf,
            )?;
            let pick = argmax(&self.score_buf);
            self.last_acq = Some(self.score_buf[pick]);
            let chosen = pool.row(pick).to_vec();
            if j + 1 < q {
                self.fantasize(&chosen, best)?;
            }
            out.extend_from_slice(&chosen);
        }
        self.stats.proposed += q as u64;
        Ok(Matrix::from_vec(q, d, out))
    }

    /// Absorb the constant lie `y = best` at a just-picked point so the
    /// next pick of this batch avoids it. Online models take the lie
    /// incrementally; fit-once models refit on history ∪ lies (the
    /// documented fallback — one O(n³/k²) fit per extra batch point).
    fn fantasize(&mut self, x: &[f64], lie: f64) -> Result<()> {
        // Record the lie and mark the model dirty *first*, so even a
        // failed absorption leaves the state flagged for a truth refit.
        self.fantasy_x.extend_from_slice(x);
        self.fantasy_y.push(lie);
        self.fantasies += 1;
        let took_lie = match self.model.as_mut().and_then(|m| m.as_online_mut()) {
            Some(online) => {
                online.observe(x, lie).context("constant-liar fantasy failed")?;
                true
            }
            None => false,
        };
        if !took_lie {
            let mut fx = self.x.clone();
            fx.extend_from_slice(&self.fantasy_x);
            let mut fy = self.y.clone();
            fy.extend_from_slice(&self.fantasy_y);
            self.fit_on(fx, fy)?;
        }
        Ok(())
    }

    /// Full truth-only refit (fresh hyper-parameter search).
    fn refit(&mut self) -> Result<()> {
        let (x, y) = (self.x.clone(), self.y.clone());
        self.fit_on(x, y)?;
        self.fantasies = 0;
        self.fantasy_x.clear();
        self.fantasy_y.clear();
        self.since_refit = 0;
        self.drift.reset();
        Ok(())
    }

    /// Fit the spec on the given raw-unit data behind a fresh
    /// standardizer (the same recipe as `ckrig fit` and the serving
    /// refit engine), and install the wrapped model.
    fn fit_on(&mut self, x: Vec<f64>, y: Vec<f64>) -> Result<()> {
        let d = self.dim();
        let ds = Dataset::new("optimize", Matrix::from_vec(y.len(), d, x), y);
        let std = Standardizer::fit(&ds);
        let tr = std.transform(&ds);
        let phase = self.cfg.telemetry.as_ref().map(|s| s.nested().phase("refit"));
        let mut opts = self.cfg.fit.clone();
        if opts.hyperopt.telemetry.is_none() {
            opts.hyperopt.telemetry = self.cfg.telemetry.as_ref().map(|s| s.nested());
        }
        let inner = self
            .cfg
            .spec
            .fit(&tr, &opts)
            .with_context(|| format!("fitting {} on {} points", self.cfg.spec, ds.n()))?;
        drop(phase);
        self.model = Some(Box::new(Standardized::new(inner, std)));
        self.stats.fits += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::functions::by_name;

    fn himmelblau_cfg(spec: &str, seed: u64) -> OptimizerConfig {
        OptimizerConfig {
            init: 12,
            pool: 256,
            seed,
            ..OptimizerConfig::new(SurrogateSpec::parse(spec).unwrap())
        }
    }

    /// Drive a full seeded EGO loop on a benchmark; returns best value.
    fn run_ego(spec: &str, budget: usize, seed: u64) -> (Optimizer, f64) {
        let bench = by_name("himmelblau").unwrap();
        let (lo, hi) = bench.domain;
        let bounds = Bounds::cube(2, lo, hi).unwrap();
        let mut opt = Optimizer::new(bounds, himmelblau_cfg(spec, seed)).unwrap();
        for _ in 0..budget {
            let xs = opt.ask(1).unwrap();
            let x = xs.row(0).to_vec();
            opt.tell(&x, (bench.eval)(&x)).unwrap();
        }
        let best = opt.best().unwrap().1;
        (opt, best)
    }

    #[test]
    fn design_phase_then_model_phase() {
        let bounds = Bounds::cube(2, -1.0, 1.0).unwrap();
        let mut opt = Optimizer::new(
            bounds,
            OptimizerConfig {
                init: 4,
                pool: 64,
                ..OptimizerConfig::new(SurrogateSpec::FullKriging)
            },
        )
        .unwrap();
        assert!(opt.best().is_none());
        // First asks are pure design — no model gets fitted.
        for i in 0..4 {
            let xs = opt.ask(1).unwrap();
            assert!(opt.bounds().contains(xs.row(0)));
            opt.tell(xs.row(0), i as f64).unwrap();
        }
        assert_eq!(opt.stats().fits, 0);
        // The next ask crosses into model-based proposals.
        let xs = opt.ask(1).unwrap();
        assert!(opt.bounds().contains(xs.row(0)));
        assert_eq!(opt.stats().fits, 1);
        assert_eq!(opt.n_observed(), 4);
        assert_eq!(opt.stats().proposed, 5);
    }

    #[test]
    fn tell_validates_input() {
        let bounds = Bounds::cube(2, -1.0, 1.0).unwrap();
        let mut opt =
            Optimizer::new(bounds, OptimizerConfig::new(SurrogateSpec::FullKriging)).unwrap();
        assert!(opt.tell(&[0.0], 1.0).is_err(), "wrong dimension");
        assert!(opt.tell(&[0.0, 0.0], f64::NAN).is_err());
        assert!(opt.tell(&[f64::INFINITY, 0.0], 1.0).is_err());
        assert_eq!(opt.n_observed(), 0);
        assert!(opt.ask(0).is_err(), "q = 0");
    }

    #[test]
    fn seeded_ask_tell_is_deterministic() {
        // Two optimizers with identical seeds and identical tells must
        // propose bit-identical points at every step — including across
        // the design→model transition and a q=3 constant-liar batch.
        let bench = by_name("himmelblau").unwrap();
        let (lo, hi) = bench.domain;
        let mk = || {
            Optimizer::new(
                Bounds::cube(2, lo, hi).unwrap(),
                himmelblau_cfg("gmmck:2", 41),
            )
            .unwrap()
        };
        let (mut a, mut b) = (mk(), mk());
        for round in 0..6 {
            let q = if round == 4 { 3 } else { 1 };
            let xa = a.ask(q).unwrap();
            let xb = b.ask(q).unwrap();
            assert_eq!(xa.max_abs_diff(&xb), 0.0, "round {round} diverged");
            for i in 0..xa.rows() {
                let x = xa.row(i).to_vec();
                let y = (bench.eval)(&x);
                a.tell(&x, y).unwrap();
                b.tell(&x, y).unwrap();
            }
        }
        assert_eq!(a.stats(), b.stats());
    }

    #[test]
    fn proposals_stay_in_bounds_prop() {
        use crate::util::proptest::{check, gen_size, Config};
        // Full fits are expensive; a handful of randomized cases covers
        // the design phase, the model phase and batch fantasization.
        check(&Config { cases: 6, seed: 0x0497 }, |rng| {
            let d = gen_size(rng, 1, 3);
            let lo: Vec<f64> = (0..d).map(|_| rng.uniform_in(-5.0, 0.0)).collect();
            let hi: Vec<f64> = lo.iter().map(|&l| l + rng.uniform_in(0.5, 10.0)).collect();
            let bounds = Bounds::new(lo, hi).map_err(|e| e.to_string())?;
            let mut opt = Optimizer::new(
                bounds,
                OptimizerConfig {
                    init: 6,
                    pool: 64,
                    local: 8,
                    seed: rng.next_u64(),
                    ..OptimizerConfig::new(SurrogateSpec::FullKriging)
                },
            )
            .map_err(|e| e.to_string())?;
            for round in 0..5 {
                let q = 1 + (round % 3);
                let xs = opt.ask(q).map_err(|e| e.to_string())?;
                crate::prop_assert!(xs.rows() == q);
                for i in 0..q {
                    let row = xs.row(i);
                    crate::prop_assert!(
                        opt.bounds().contains(row),
                        "round {round} proposal {i} escaped: {row:?}"
                    );
                    let y: f64 = row.iter().map(|v| v * v).sum();
                    opt.tell(&row.to_vec(), y).map_err(|e| e.to_string())?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batch_ask_spreads_points_and_recovers() {
        let bench = by_name("himmelblau").unwrap();
        let (lo, hi) = bench.domain;
        let mut opt = Optimizer::new(
            Bounds::cube(2, lo, hi).unwrap(),
            himmelblau_cfg("kriging", 7),
        )
        .unwrap();
        for _ in 0..12 {
            let xs = opt.ask(1).unwrap();
            let x = xs.row(0).to_vec();
            opt.tell(&x, (bench.eval)(&x)).unwrap();
        }
        let fits_before = opt.stats().fits;
        let batch = opt.ask(4).unwrap();
        assert_eq!(batch.rows(), 4);
        // Constant liar must spread the batch: no two picks (nearly)
        // coincide.
        for i in 0..4 {
            for j in (i + 1)..4 {
                let dist = crate::util::stats::dist(batch.row(i), batch.row(j));
                assert!(dist > 1e-6, "batch points {i} and {j} coincide");
            }
        }
        // The lies polluted the model; the next tell + ask refits once.
        let x = batch.row(0).to_vec();
        opt.tell(&x, (bench.eval)(&x)).unwrap();
        let _ = opt.ask(1).unwrap();
        assert!(opt.stats().fits > fits_before, "fantasies were never flushed");
    }

    #[test]
    fn incremental_tell_feeds_online_surrogates() {
        let (opt, _) = run_ego("gmmck:2", 20, 13);
        let s = opt.stats();
        // After the design phase the model absorbs tells incrementally
        // (GMMCK routes each point to one cluster) rather than refitting
        // per evaluation.
        assert!(s.incremental > 0, "no incremental observes: {s:?}");
        assert!(
            s.fits < s.told,
            "every tell refitted — the online path never engaged: {s:?}"
        );
    }

    #[test]
    fn staleness_policy_schedules_refits() {
        let bench = by_name("himmelblau").unwrap();
        let (lo, hi) = bench.domain;
        let mut cfg = himmelblau_cfg("kriging", 3);
        cfg.policy = OnlinePolicy {
            staleness_budget: 4,
            drift_zscore: 1e9,
            ..OnlinePolicy::default()
        };
        let mut opt = Optimizer::new(Bounds::cube(2, lo, hi).unwrap(), cfg).unwrap();
        for _ in 0..24 {
            let xs = opt.ask(1).unwrap();
            let x = xs.row(0).to_vec();
            opt.tell(&x, (bench.eval)(&x)).unwrap();
        }
        // 12 post-design evaluations with a budget of 4 → at least three
        // full θ-refreshing fits beyond the initial one.
        assert!(opt.stats().fits >= 3, "{:?}", opt.stats());
    }

    #[test]
    fn ego_with_cluster_kriging_beats_random_on_himmelblau() {
        let budget = 60;
        let (_, ego_best) = run_ego("mtck:4", budget, 17);
        // Random search with the same evaluation budget and domain.
        let bench = by_name("himmelblau").unwrap();
        let (lo, hi) = bench.domain;
        let mut rng = Rng::new(17);
        let mut rand_best = f64::INFINITY;
        for _ in 0..budget {
            let p = [rng.uniform_in(lo, hi), rng.uniform_in(lo, hi)];
            rand_best = rand_best.min((bench.eval)(&p));
        }
        assert!(
            ego_best < rand_best,
            "EGO ({ego_best:.4}) did not beat random search ({rand_best:.4})"
        );
        // And it should get genuinely close to one of the four optima.
        assert!(ego_best < 1.0, "EGO best {ego_best:.4} nowhere near an optimum");
    }
}
