//! Acquisition functions: turn a surrogate posterior into a "how useful
//! is evaluating here" score (minimization convention throughout).
//!
//! The three classics of the EGO lineage (Jones et al. 1998):
//!
//! * **Expected Improvement** — `EI = γ·Φ(γ/σ) + σ·φ(γ/σ)` with
//!   `γ = best − μ − ξ`; the workhorse default, balancing the posterior
//!   mean against the Kriging variance the paper's introduction motivates
//!   as the exploration signal.
//! * **Probability of Improvement** — `PI = Φ(γ/σ)`; greedier, ignores
//!   the improvement's magnitude.
//! * **Lower Confidence Bound** — `−(μ − κσ)`; a tunable
//!   exploration/exploitation dial with no incumbent dependence.
//!
//! All scores are *maximized* by the proposal loop (LCB is negated so one
//! argmax serves all three), and all use the shared erf-based normal CDF
//! from [`crate::util::stats`] (Abramowitz–Stegun 7.1.26, ~1.5e-7 max
//! error, odd by construction) instead of each caller hand-rolling its
//! own tail approximation.

use crate::kriging::Surrogate;
use crate::util::matrix::Matrix;
use crate::util::stats::{norm_cdf, norm_pdf};
use anyhow::{Context, Result};

/// A posterior standard deviation below this is treated as zero (the
/// model is certain): the acquisition degenerates to its deterministic
/// limit instead of dividing by a vanishing σ.
const SD_FLOOR: f64 = 1e-12;

/// An acquisition function under the **minimization** convention: larger
/// score ⇒ more attractive candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Acquisition {
    /// Expected Improvement over the incumbent, with exploration margin
    /// `xi` (ξ ≥ 0 shifts the improvement threshold below the incumbent).
    ExpectedImprovement { xi: f64 },
    /// Probability of improving on the incumbent by at least `xi`.
    ProbabilityOfImprovement { xi: f64 },
    /// Negated lower confidence bound `−(μ − κσ)`; `kappa` ≥ 0 scales the
    /// exploration bonus.
    LowerConfidenceBound { kappa: f64 },
}

impl Acquisition {
    /// Expected Improvement with the conventional ξ = 0.
    pub fn ei() -> Self {
        Acquisition::ExpectedImprovement { xi: 0.0 }
    }

    /// Probability of Improvement with a small ξ (pure PI with ξ = 0
    /// collapses onto the incumbent; 0.01 is the usual nudge).
    pub fn poi() -> Self {
        Acquisition::ProbabilityOfImprovement { xi: 0.01 }
    }

    /// Lower Confidence Bound with the conventional κ = 2.
    pub fn lcb() -> Self {
        Acquisition::LowerConfidenceBound { kappa: 2.0 }
    }

    /// Short name for reports and the CLI.
    pub fn name(&self) -> &'static str {
        match self {
            Acquisition::ExpectedImprovement { .. } => "ei",
            Acquisition::ProbabilityOfImprovement { .. } => "poi",
            Acquisition::LowerConfidenceBound { .. } => "lcb",
        }
    }

    /// Parse the CLI form: `ei`, `ei:0.05`, `poi`, `poi:0.1`, `lcb`,
    /// `lcb:2.5` (the optional number is ξ for EI/PI, κ for LCB).
    pub fn parse(s: &str) -> Result<Self> {
        let (head, param) = match s.split_once(':') {
            Some((h, p)) => {
                let v: f64 = p
                    .trim()
                    .parse()
                    .with_context(|| format!("bad parameter {p:?} in acquisition {s:?}"))?;
                anyhow::ensure!(
                    v.is_finite() && v >= 0.0,
                    "acquisition parameter must be finite and ≥ 0, got {v}"
                );
                (h.trim(), Some(v))
            }
            None => (s.trim(), None),
        };
        Ok(match head.to_ascii_lowercase().as_str() {
            "ei" => Acquisition::ExpectedImprovement { xi: param.unwrap_or(0.0) },
            "poi" | "pi" => Acquisition::ProbabilityOfImprovement { xi: param.unwrap_or(0.01) },
            "lcb" | "ucb" => Acquisition::LowerConfidenceBound { kappa: param.unwrap_or(2.0) },
            other => anyhow::bail!("unknown acquisition {other:?} (expected ei/poi/lcb)"),
        })
    }

    /// Score one posterior `(mean, variance)` against the incumbent
    /// `best` (the smallest observed value). Deterministic (σ → 0)
    /// posteriors degenerate gracefully: EI → max(improvement, 0),
    /// PI → {0, 1}, LCB → −μ.
    pub fn score(self, mean: f64, variance: f64, best: f64) -> f64 {
        let sd = variance.max(0.0).sqrt();
        match self {
            Acquisition::ExpectedImprovement { xi } => {
                let gamma = best - mean - xi;
                if sd < SD_FLOOR {
                    return gamma.max(0.0);
                }
                let z = gamma / sd;
                gamma * norm_cdf(z) + sd * norm_pdf(z)
            }
            Acquisition::ProbabilityOfImprovement { xi } => {
                let gamma = best - mean - xi;
                if sd < SD_FLOOR {
                    return if gamma > 0.0 { 1.0 } else { 0.0 };
                }
                norm_cdf(gamma / sd)
            }
            Acquisition::LowerConfidenceBound { kappa } => -(mean - kappa * sd),
        }
    }

    /// Score every row of `cands` through one batched
    /// [`Surrogate::predict_into`] call — the hot path the 10k-candidate
    /// pools ride. `mean`/`var`/`out` are caller-owned scratch buffers,
    /// resized here and reusable across calls (allocation-free steady
    /// state, same discipline as the serving Batcher).
    pub fn score_batch_into(
        &self,
        model: &dyn Surrogate,
        cands: &Matrix,
        best: f64,
        mean: &mut Vec<f64>,
        var: &mut Vec<f64>,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        let n = cands.rows();
        mean.resize(n, 0.0);
        var.resize(n, 0.0);
        out.resize(n, 0.0);
        model
            .predict_into(cands, &mut mean[..n], &mut var[..n])
            .context("acquisition: posterior evaluation failed")?;
        for i in 0..n {
            out[i] = self.score(mean[i], var[i], best);
        }
        Ok(())
    }
}

impl std::fmt::Display for Acquisition {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Acquisition::ExpectedImprovement { xi } => write!(f, "ei:{xi}"),
            Acquisition::ProbabilityOfImprovement { xi } => write!(f, "poi:{xi}"),
            Acquisition::LowerConfidenceBound { kappa } => write!(f, "lcb:{kappa}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kriging::Prediction;

    #[test]
    fn ei_closed_form_and_limits() {
        let ei = Acquisition::ei();
        // γ = 1, σ = 1: EI = Φ(1) + φ(1) ≈ 0.8413 + 0.2420 = 1.0833.
        let v = ei.score(0.0, 1.0, 1.0);
        assert!((v - 1.083_31).abs() < 1e-4, "{v}");
        // Far above the incumbent with tiny σ → essentially zero.
        assert!(ei.score(10.0, 0.01, 0.0) < 1e-12);
        // Deterministic posterior degenerates to max(improvement, 0).
        assert_eq!(ei.score(2.0, 0.0, 5.0), 3.0);
        assert_eq!(ei.score(7.0, 0.0, 5.0), 0.0);
        // EI is non-negative everywhere.
        for (m, s2, b) in [(3.0, 0.5, 1.0), (-2.0, 2.0, -3.0), (0.0, 1e-8, -1.0)] {
            assert!(ei.score(m, s2, b) >= 0.0, "EI({m},{s2},{b})");
        }
    }

    #[test]
    fn ei_prefers_uncertainty_at_equal_mean() {
        let ei = Acquisition::ei();
        let low = ei.score(1.0, 0.1, 0.5);
        let high = ei.score(1.0, 2.0, 0.5);
        assert!(high > low, "{high} vs {low}");
    }

    #[test]
    fn poi_is_a_probability() {
        let poi = Acquisition::ProbabilityOfImprovement { xi: 0.0 };
        for (m, s2, b) in [(0.0, 1.0, 1.0), (5.0, 0.2, 1.0), (-3.0, 4.0, 0.0)] {
            let v = poi.score(m, s2, b);
            assert!((0.0..=1.0).contains(&v), "PI({m},{s2},{b}) = {v}");
        }
        // Mean exactly at the incumbent: 50/50.
        assert!((poi.score(1.0, 1.0, 1.0) - 0.5).abs() < 1e-9);
        // Deterministic limits.
        assert_eq!(poi.score(0.0, 0.0, 1.0), 1.0);
        assert_eq!(poi.score(2.0, 0.0, 1.0), 0.0);
    }

    #[test]
    fn lcb_trades_mean_against_uncertainty() {
        let lcb = Acquisition::LowerConfidenceBound { kappa: 2.0 };
        // Lower mean wins at equal σ; higher σ wins at equal mean.
        assert!(lcb.score(1.0, 1.0, 0.0) > lcb.score(2.0, 1.0, 0.0));
        assert!(lcb.score(1.0, 4.0, 0.0) > lcb.score(1.0, 1.0, 0.0));
        // κ = 0 is pure exploitation: score is −μ, σ ignored.
        let greedy = Acquisition::LowerConfidenceBound { kappa: 0.0 };
        assert_eq!(greedy.score(3.0, 100.0, 0.0), -3.0);
    }

    #[test]
    fn parse_display_roundtrip() {
        for acq in [
            Acquisition::ExpectedImprovement { xi: 0.0 },
            Acquisition::ExpectedImprovement { xi: 0.05 },
            Acquisition::ProbabilityOfImprovement { xi: 0.01 },
            Acquisition::LowerConfidenceBound { kappa: 2.5 },
        ] {
            let text = acq.to_string();
            assert_eq!(Acquisition::parse(&text).unwrap(), acq, "via {text:?}");
        }
        assert_eq!(Acquisition::parse("EI").unwrap(), Acquisition::ei());
        assert_eq!(Acquisition::parse("lcb").unwrap(), Acquisition::lcb());
        assert!(Acquisition::parse("bogus").is_err());
        assert!(Acquisition::parse("ei:abc").is_err());
        assert!(Acquisition::parse("ei:-1").is_err());
    }

    /// Fixed-posterior double for the batch path.
    struct Flat {
        mean: f64,
        var: f64,
    }
    impl Surrogate for Flat {
        fn predict(&self, xt: &Matrix) -> Result<Prediction> {
            Ok(Prediction {
                mean: (0..xt.rows()).map(|i| self.mean + xt[(i, 0)]).collect(),
                variance: vec![self.var; xt.rows()],
            })
        }
        fn name(&self) -> &str {
            "flat"
        }
        fn dim(&self) -> usize {
            1
        }
    }

    #[test]
    fn batch_scores_match_scalar_scores() {
        let model = Flat { mean: 0.5, var: 0.7 };
        let cands = Matrix::from_vec(4, 1, vec![-1.0, 0.0, 0.5, 2.0]);
        let (mut m, mut v, mut s) = (Vec::new(), Vec::new(), Vec::new());
        for acq in [Acquisition::ei(), Acquisition::poi(), Acquisition::lcb()] {
            acq.score_batch_into(&model, &cands, 0.3, &mut m, &mut v, &mut s).unwrap();
            for i in 0..4 {
                let expect = acq.score(0.5 + cands[(i, 0)], 0.7, 0.3);
                assert_eq!(s[i], expect, "{acq} row {i}");
            }
        }
    }
}
